//! Model registry: the set of engines one serving process hosts.
//!
//! Protocol v2 routes requests by a `u16` model id; the registry is the
//! authority mapping ids (dense, assigned in registration order) and
//! human-readable names to engines. Model id 0 is the **default model**,
//! which also serves protocol-v1 clients that cannot name a model.
//!
//! Construction is where multi-model serving pays its safety tax once:
//! every engine is [`Engine::validate`]d (dimension chains + weight
//! shapes), names are checked unique, and the worst-case
//! [`ScratchDims`] union over all models is computed so the shared
//! worker pool can pre-size per-worker scratch for the largest model —
//! heterogeneous shapes then reuse the same buffers allocation-free.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::engine::{Engine, ScratchDims};
use super::synth;
use crate::config::{ModelSource, ModelSpec, PolicyOverrides};

/// Upper bound on hosted models: far above any deployment this serves,
/// small enough that per-model queues/batchers/stats stay cheap. (The
/// wire format would allow u16::MAX + 1.)
pub const MAX_MODELS: usize = 1024;

/// One hosted model: routing name + its engine + its serving-policy
/// overrides (the `;key=value` tail of its `--model` spec). Overrides
/// are resolved against the server-level defaults into a
/// [`crate::server::sched::Policy`] when a server binds the registry —
/// the registry itself stays server-config-agnostic.
pub struct ModelEntry {
    pub name: String,
    pub engine: Arc<Engine>,
    pub policy: PolicyOverrides,
}

/// Immutable set of models behind one server / worker pool. Ids are the
/// construction order: 0 is the default (v1-compat) model.
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
    scratch_dims: ScratchDims,
}

impl ModelRegistry {
    /// Build and validate a registry. `entries` order assigns model
    /// ids; every model keeps the server-default serving policy.
    pub fn new(entries: Vec<(String, Arc<Engine>)>) -> Result<ModelRegistry> {
        ModelRegistry::with_policies(
            entries
                .into_iter()
                .map(|(n, e)| (n, e, PolicyOverrides::default()))
                .collect(),
        )
    }

    /// [`ModelRegistry::new`] with per-model serving-policy overrides.
    pub fn with_policies(
        entries: Vec<(String, Arc<Engine>, PolicyOverrides)>,
    ) -> Result<ModelRegistry> {
        if entries.is_empty() {
            bail!("model registry needs at least one model (id 0 serves v1 clients)");
        }
        if entries.len() > MAX_MODELS {
            bail!("model registry holds {} models, max {MAX_MODELS}", entries.len());
        }
        let mut dims = ScratchDims::default();
        let mut out = Vec::with_capacity(entries.len());
        for (name, engine, policy) in entries {
            if name.is_empty() {
                bail!("model name must be non-empty");
            }
            if out.iter().any(|e: &ModelEntry| e.name == name) {
                bail!("duplicate model name {name:?} in registry");
            }
            engine
                .validate()
                .map_err(|e| e.context(format!("registering model {name:?}")))?;
            // Pack B panels for the tiled GEMM here, once, so the
            // serving path never pays the pack cost.
            engine.ensure_packed();
            dims = dims.union(engine.scratch_dims());
            out.push(ModelEntry {
                name,
                engine,
                policy,
            });
        }
        Ok(ModelRegistry {
            entries: out,
            scratch_dims: dims,
        })
    }

    /// Single-model registry (the pre-v2 server shape): the engine's
    /// topology name becomes the routing name.
    pub fn single(engine: Arc<Engine>) -> Result<ModelRegistry> {
        let name = engine.topo.name.clone();
        ModelRegistry::new(vec![(name, engine)])
    }

    /// Build a registry from parsed `--model` specs (id order = spec
    /// order). Synthetic specs build directly; each manifest spec is
    /// delegated to `manifest_engine` — quantized via the PJRT
    /// calibration path in `pjrt` builds, full-precision via
    /// [`crate::nn::loader::FpManifestBuilder`] otherwise. This is the
    /// ONE spec→engine loop shared by `aquant serve` and the serve
    /// example, so the two cannot drift.
    pub fn from_specs(
        specs: &[ModelSpec],
        mut manifest_engine: impl FnMut(&ModelSpec) -> Result<Engine>,
    ) -> Result<ModelRegistry> {
        let mut entries = Vec::with_capacity(specs.len());
        for spec in specs {
            let engine = match &spec.source {
                ModelSource::Synth { kind, seed } => synth::engine_from_spec(kind, *seed)?,
                ModelSource::Manifest { .. } => manifest_engine(spec)?,
            };
            entries.push((spec.name.clone(), Arc::new(engine), spec.policy.clone()));
        }
        ModelRegistry::with_policies(entries)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entry by wire model id.
    pub fn get(&self, id: u16) -> Option<&ModelEntry> {
        self.entries.get(id as usize)
    }

    /// The v1-compat default model (id 0).
    pub fn default_entry(&self) -> &ModelEntry {
        &self.entries[0]
    }

    /// Wire id for a routing name.
    pub fn id_of(&self, name: &str) -> Option<u16> {
        self.entries
            .iter()
            .position(|e| e.name == name)
            .map(|i| i as u16)
    }

    /// `(id, entry)` in id order.
    pub fn iter(&self) -> impl Iterator<Item = (u16, &ModelEntry)> {
        self.entries.iter().enumerate().map(|(i, e)| (i as u16, e))
    }

    /// Max-dims union over all hosted models — what each shared-pool
    /// worker's scratch must accommodate.
    pub fn scratch_dims(&self) -> ScratchDims {
        self.scratch_dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::synth;
    use crate::util::rng::Rng;

    fn engine(seed: u64) -> Arc<Engine> {
        let mut rng = Rng::new(seed);
        let (topo, weights) = synth::tiny_model(&mut rng);
        Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ))
    }

    #[test]
    fn ids_follow_registration_order() {
        let reg = ModelRegistry::new(vec![
            ("a".into(), engine(1)),
            ("b".into(), engine(2)),
        ])
        .unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.id_of("a"), Some(0));
        assert_eq!(reg.id_of("b"), Some(1));
        assert_eq!(reg.id_of("c"), None);
        assert_eq!(reg.default_entry().name, "a");
        assert!(reg.get(2).is_none());
        assert_eq!(reg.get(1).unwrap().name, "b");
    }

    #[test]
    fn rejects_empty_and_duplicates() {
        assert!(ModelRegistry::new(vec![]).is_err());
        assert!(ModelRegistry::new(vec![
            ("m".into(), engine(1)),
            ("m".into(), engine(2)),
        ])
        .is_err());
        assert!(ModelRegistry::new(vec![("".into(), engine(1))]).is_err());
    }

    #[test]
    fn from_specs_builds_synth_and_delegates_manifest() {
        let specs = vec![
            ModelSpec::parse("a=synth:tiny", None, None).unwrap(),
            ModelSpec::parse("b=synth:bench:7", None, None).unwrap(),
        ];
        let reg = ModelRegistry::from_specs(&specs, |_| unreachable!("no manifest specs"))
            .unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.id_of("a"), Some(0));
        assert_eq!(reg.id_of("b"), Some(1));
        // a manifest spec reaches the delegate, and its error propagates
        let specs = vec![ModelSpec::parse("m:nearest:W32A32", None, None).unwrap()];
        let err = ModelRegistry::from_specs(&specs, |s| {
            Err(anyhow::anyhow!("no artifacts for {}", s.name))
        })
        .unwrap_err();
        assert!(err.to_string().contains("no artifacts for m"), "{err}");
    }

    #[test]
    fn entries_carry_policy_overrides() {
        // plain `new` -> empty overrides (server defaults)
        let reg = ModelRegistry::new(vec![("a".into(), engine(1))]).unwrap();
        assert!(reg.get(0).unwrap().policy.is_empty());

        // spec policy tails ride into the entries
        let specs = vec![
            ModelSpec::parse("a=synth:tiny;weight=3;max_batch=8", None, None).unwrap(),
            ModelSpec::parse("b=synth:bench:7", None, None).unwrap(),
        ];
        let reg = ModelRegistry::from_specs(&specs, |_| unreachable!()).unwrap();
        assert_eq!(reg.get(0).unwrap().policy.weight, Some(3));
        assert_eq!(reg.get(0).unwrap().policy.max_batch, Some(8));
        assert!(reg.get(1).unwrap().policy.is_empty());
    }

    #[test]
    fn rejects_invalid_engine() {
        let mut rng = Rng::new(3);
        let (topo, mut weights) = synth::tiny_model(&mut rng);
        // truncate one layer's weights: must fail at registration, not
        // mid-request in a pool worker
        weights.get_mut("c1").unwrap().w.pop();
        let eng = Arc::new(Engine::new(topo, weights));
        assert!(ModelRegistry::single(eng).is_err());
    }

    #[test]
    fn scratch_dims_cover_all_models() {
        let mut rng = Rng::new(4);
        let (t1, w1) = synth::tiny_model(&mut rng);
        let (t2, w2) = synth::bench_model(&mut rng);
        let e1 = Arc::new(Engine::new(t1, w1));
        let e2 = Arc::new(Engine::new(t2, w2));
        let (d1, d2) = (e1.scratch_dims(), e2.scratch_dims());
        let reg =
            ModelRegistry::new(vec![("tiny".into(), e1), ("bench".into(), e2)]).unwrap();
        let d = reg.scratch_dims();
        for (a, b) in [(d1, d), (d2, d)] {
            assert!(
                a.acts <= b.acts
                    && a.patches <= b.patches
                    && a.apanel <= b.apanel
                    && a.quant <= b.quant
            );
        }
        assert_eq!(d, d1.union(d2));
    }
}
