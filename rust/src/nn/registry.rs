//! Model registry: the set of engines one serving process hosts.
//!
//! Protocol v2 routes requests by a `u16` model id; the registry is the
//! authority mapping ids (dense, assigned in registration order) and
//! human-readable names to engines. Model id 0 is the **default model**,
//! which also serves protocol-v1 clients that cannot name a model.
//!
//! Construction is where multi-model serving pays its safety tax once:
//! every engine is [`Engine::validate`]d (dimension chains + weight
//! shapes), names are checked unique, and the worst-case
//! [`ScratchDims`] union over all models is computed so the shared
//! worker pool can pre-size per-worker scratch for the largest model —
//! heterogeneous shapes then reuse the same buffers allocation-free.
//!
//! Registries are **epoch-versioned** for the control plane: a running
//! server swaps one `Arc<ModelRegistry>` for the next (built by
//! [`ModelRegistry::with_added`] / [`with_removed`] / [`with_policy`]),
//! never mutates one in place. The derived-registry rules keep every
//! already-issued wire id meaningful across swaps:
//!
//! - **ids are append-only**: a slot index is assigned once and never
//!   reused; removing a model leaves a tombstone (`None` slot) so the
//!   id answers "unknown model" forever after — exactly what the
//!   describe protocol's `img_elems == 0` convention already encodes;
//! - **scratch dims are grow-only**: the union only ever accumulates,
//!   so worker scratch sized for epoch N fits every epoch ≤ N and
//!   in-flight batches never outgrow their buffers mid-swap;
//! - each entry records `added_at_epoch` for observability.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::engine::{Engine, ScratchDims};
use super::synth;
use crate::config::{ModelSource, ModelSpec, PolicyOverrides};

/// Upper bound on hosted models: far above any deployment this serves,
/// small enough that per-model queues/batchers/stats stay cheap. (The
/// wire format would allow u16::MAX + 1.) With the control plane this
/// bounds *slots ever assigned*, not just live models — tombstones
/// count, so a churny add/remove loop eventually needs a restart.
pub const MAX_MODELS: usize = 1024;

/// One hosted model: routing name + its engine + its serving-policy
/// overrides (the `;key=value` tail of its `--model` spec or a later
/// admin `policy` command). Overrides are resolved against the
/// server-level defaults into a [`crate::server::sched::Policy`] when a
/// server binds or swaps the registry — the registry itself stays
/// server-config-agnostic.
#[derive(Clone)]
pub struct ModelEntry {
    pub name: String,
    pub engine: Arc<Engine>,
    pub policy: PolicyOverrides,
    /// Registry epoch this model first appeared in (0 = present at
    /// bind). Survives policy retunes; surfaced in `/stats`.
    pub added_at_epoch: u64,
}

/// Immutable snapshot of the models behind one server / worker pool at
/// one epoch. Slot index = wire model id; 0 is the default (v1-compat)
/// model. `None` slots are tombstones left by removed models.
pub struct ModelRegistry {
    slots: Vec<Option<ModelEntry>>,
    scratch_dims: ScratchDims,
    epoch: u64,
}

impl ModelRegistry {
    /// Build and validate an epoch-0 registry. `entries` order assigns
    /// model ids; every model keeps the server-default serving policy.
    pub fn new(entries: Vec<(String, Arc<Engine>)>) -> Result<ModelRegistry> {
        ModelRegistry::with_policies(
            entries
                .into_iter()
                .map(|(n, e)| (n, e, PolicyOverrides::default()))
                .collect(),
        )
    }

    /// [`ModelRegistry::new`] with per-model serving-policy overrides.
    pub fn with_policies(
        entries: Vec<(String, Arc<Engine>, PolicyOverrides)>,
    ) -> Result<ModelRegistry> {
        if entries.is_empty() {
            bail!("model registry needs at least one model (id 0 serves v1 clients)");
        }
        if entries.len() > MAX_MODELS {
            bail!("model registry holds {} models, max {MAX_MODELS}", entries.len());
        }
        let mut dims = ScratchDims::default();
        let mut out: Vec<Option<ModelEntry>> = Vec::with_capacity(entries.len());
        for (name, engine, policy) in entries {
            validate_entry(&name, &engine, out.iter().flatten())?;
            dims = dims.union(engine.scratch_dims());
            out.push(Some(ModelEntry {
                name,
                engine,
                policy,
                added_at_epoch: 0,
            }));
        }
        Ok(ModelRegistry {
            slots: out,
            scratch_dims: dims,
            epoch: 0,
        })
    }

    /// Single-model registry (the pre-v2 server shape): the engine's
    /// topology name becomes the routing name.
    pub fn single(engine: Arc<Engine>) -> Result<ModelRegistry> {
        let name = engine.topo.name.clone();
        ModelRegistry::new(vec![(name, engine)])
    }

    /// Build a registry from parsed `--model` specs (id order = spec
    /// order). Synthetic specs build directly; each manifest spec is
    /// delegated to `manifest_engine` — quantized via the PJRT
    /// calibration path in `pjrt` builds, full-precision via
    /// [`crate::nn::loader::FpManifestBuilder`] otherwise. This is the
    /// ONE spec→engine loop shared by `aquant serve` and the serve
    /// example, so the two cannot drift.
    pub fn from_specs(
        specs: &[ModelSpec],
        mut manifest_engine: impl FnMut(&ModelSpec) -> Result<Engine>,
    ) -> Result<ModelRegistry> {
        let mut entries = Vec::with_capacity(specs.len());
        for spec in specs {
            let engine = match &spec.source {
                ModelSource::Synth { kind, seed } => synth::engine_from_spec(kind, *seed)?,
                ModelSource::Manifest { .. } => manifest_engine(spec)?,
            };
            entries.push((spec.name.clone(), Arc::new(engine), spec.policy.clone()));
        }
        ModelRegistry::with_policies(entries)
    }

    /// Next-epoch registry with `name` appended at a fresh slot id.
    /// Rejects duplicate live names (a tombstoned name may be re-added
    /// — it gets a NEW id; the old id stays dead) and invalid engines;
    /// scratch dims grow by union, never shrink.
    pub fn with_added(
        &self,
        name: &str,
        engine: Arc<Engine>,
        policy: PolicyOverrides,
    ) -> Result<ModelRegistry> {
        if self.slots.len() >= MAX_MODELS {
            bail!(
                "registry has assigned all {MAX_MODELS} model slots (ids are \
                 append-only; removed slots are not reused)"
            );
        }
        validate_entry(name, &engine, self.live())?;
        let mut slots = self.slots.clone();
        let epoch = self.epoch + 1;
        let dims = self.scratch_dims.union(engine.scratch_dims());
        slots.push(Some(ModelEntry {
            name: name.to_string(),
            engine,
            policy,
            added_at_epoch: epoch,
        }));
        Ok(ModelRegistry {
            slots,
            scratch_dims: dims,
            epoch,
        })
    }

    /// Next-epoch registry with `name` tombstoned: its id keeps
    /// answering "unknown model" forever. Rejects unknown names and
    /// removing the last live model (an empty registry cannot serve).
    pub fn with_removed(&self, name: &str) -> Result<ModelRegistry> {
        let Some(id) = self.id_of(name) else {
            bail!("no model named {name:?} to remove");
        };
        if self.live().count() == 1 {
            bail!("cannot remove {name:?}: it is the last live model");
        }
        let mut slots = self.slots.clone();
        slots[id as usize] = None;
        Ok(ModelRegistry {
            slots,
            scratch_dims: self.scratch_dims, // grow-only: keep the union
            epoch: self.epoch + 1,
        })
    }

    /// Next-epoch registry with `name`'s policy overrides updated:
    /// every `Some` field of `over` replaces the entry's value, `None`
    /// fields keep it (so `policy m weight=5` retunes one knob without
    /// resetting the rest). Bounds are enforced when the server
    /// re-resolves policies at swap time.
    pub fn with_policy(&self, name: &str, over: &PolicyOverrides) -> Result<ModelRegistry> {
        let Some(id) = self.id_of(name) else {
            bail!("no model named {name:?} to retune");
        };
        let mut slots = self.slots.clone();
        let entry = slots[id as usize].as_mut().expect("id_of returned a live id");
        let p = &mut entry.policy;
        if let Some(v) = over.max_batch {
            p.max_batch = Some(v);
        }
        if let Some(v) = over.batch_wait_us {
            p.batch_wait_us = Some(v);
        }
        if let Some(v) = over.queue_images {
            p.queue_images = Some(v);
        }
        if let Some(v) = over.weight {
            p.weight = Some(v);
        }
        if let Some(v) = over.slo_us {
            p.slo_us = Some(v);
        }
        Ok(ModelRegistry {
            slots,
            scratch_dims: self.scratch_dims,
            epoch: self.epoch + 1,
        })
    }

    /// Next-epoch registry with identical contents: the admin `reload`
    /// command — forces the scheduler/conn tier to re-resolve policies
    /// and re-publish stats rows without changing the model set.
    pub fn reloaded(&self) -> ModelRegistry {
        ModelRegistry {
            slots: self.slots.clone(),
            scratch_dims: self.scratch_dims,
            epoch: self.epoch + 1,
        }
    }

    /// Registry epoch: 0 at bind, +1 per control-plane swap.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Slots ever assigned (live + tombstones) = one past the highest
    /// wire id this registry answers for. Describe responses and
    /// per-slot server state are sized by this.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Live (non-tombstoned) model count.
    pub fn live_len(&self) -> usize {
        self.live().count()
    }

    /// Entry by wire model id; `None` for out-of-range ids AND
    /// tombstoned slots — both are the same "unknown model" to the
    /// protocol layer.
    pub fn get(&self, id: u16) -> Option<&ModelEntry> {
        self.slots.get(id as usize).and_then(Option::as_ref)
    }

    /// The v1-compat default model (id 0); `None` once it has been
    /// removed (v1 clients then get the unknown-model close, like a v2
    /// client naming a dead id).
    pub fn default_entry(&self) -> Option<&ModelEntry> {
        self.get(0)
    }

    /// Wire id for a routing name (live entries only).
    pub fn id_of(&self, name: &str) -> Option<u16> {
        self.slots
            .iter()
            .position(|e| e.as_ref().is_some_and(|e| e.name == name))
            .map(|i| i as u16)
    }

    /// Live `(id, entry)` in id order; tombstoned slots are skipped.
    pub fn iter(&self) -> impl Iterator<Item = (u16, &ModelEntry)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (i as u16, e)))
    }

    fn live(&self) -> impl Iterator<Item = &ModelEntry> {
        self.slots.iter().flatten()
    }

    /// Max-dims union over all models ever hosted (grow-only across
    /// epochs) — what each shared-pool worker's scratch must
    /// accommodate.
    pub fn scratch_dims(&self) -> ScratchDims {
        self.scratch_dims
    }
}

/// Shared add-time checks: non-empty unique name, valid engine, B
/// panels packed once so the serving path never pays the pack cost.
fn validate_entry<'a>(
    name: &str,
    engine: &Engine,
    live: impl Iterator<Item = &'a ModelEntry>,
) -> Result<()> {
    if name.is_empty() {
        bail!("model name must be non-empty");
    }
    for e in live {
        if e.name == name {
            bail!("duplicate model name {name:?} in registry");
        }
    }
    engine
        .validate()
        .map_err(|e| e.context(format!("registering model {name:?}")))?;
    engine.ensure_packed();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::synth;
    use crate::util::rng::Rng;

    fn engine(seed: u64) -> Arc<Engine> {
        let mut rng = Rng::new(seed);
        let (topo, weights) = synth::tiny_model(&mut rng);
        Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ))
    }

    #[test]
    fn ids_follow_registration_order() {
        let reg = ModelRegistry::new(vec![
            ("a".into(), engine(1)),
            ("b".into(), engine(2)),
        ])
        .unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.live_len(), 2);
        assert_eq!(reg.epoch(), 0);
        assert_eq!(reg.id_of("a"), Some(0));
        assert_eq!(reg.id_of("b"), Some(1));
        assert_eq!(reg.id_of("c"), None);
        assert_eq!(reg.default_entry().unwrap().name, "a");
        assert!(reg.get(2).is_none());
        assert_eq!(reg.get(1).unwrap().name, "b");
        assert_eq!(reg.get(1).unwrap().added_at_epoch, 0);
    }

    #[test]
    fn rejects_empty_and_duplicates() {
        assert!(ModelRegistry::new(vec![]).is_err());
        assert!(ModelRegistry::new(vec![
            ("m".into(), engine(1)),
            ("m".into(), engine(2)),
        ])
        .is_err());
        assert!(ModelRegistry::new(vec![("".into(), engine(1))]).is_err());
    }

    #[test]
    fn from_specs_builds_synth_and_delegates_manifest() {
        let specs = vec![
            ModelSpec::parse("a=synth:tiny", None, None).unwrap(),
            ModelSpec::parse("b=synth:bench:7", None, None).unwrap(),
        ];
        let reg = ModelRegistry::from_specs(&specs, |_| unreachable!("no manifest specs"))
            .unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.id_of("a"), Some(0));
        assert_eq!(reg.id_of("b"), Some(1));
        // a manifest spec reaches the delegate, and its error propagates
        let specs = vec![ModelSpec::parse("m:nearest:W32A32", None, None).unwrap()];
        let err = ModelRegistry::from_specs(&specs, |s| {
            Err(anyhow::anyhow!("no artifacts for {}", s.name))
        })
        .unwrap_err();
        assert!(err.to_string().contains("no artifacts for m"), "{err}");
    }

    #[test]
    fn entries_carry_policy_overrides() {
        // plain `new` -> empty overrides (server defaults)
        let reg = ModelRegistry::new(vec![("a".into(), engine(1))]).unwrap();
        assert!(reg.get(0).unwrap().policy.is_empty());

        // spec policy tails ride into the entries
        let specs = vec![
            ModelSpec::parse("a=synth:tiny;weight=3;max_batch=8", None, None).unwrap(),
            ModelSpec::parse("b=synth:bench:7", None, None).unwrap(),
        ];
        let reg = ModelRegistry::from_specs(&specs, |_| unreachable!()).unwrap();
        assert_eq!(reg.get(0).unwrap().policy.weight, Some(3));
        assert_eq!(reg.get(0).unwrap().policy.max_batch, Some(8));
        assert!(reg.get(1).unwrap().policy.is_empty());
    }

    #[test]
    fn rejects_invalid_engine() {
        let mut rng = Rng::new(3);
        let (topo, mut weights) = synth::tiny_model(&mut rng);
        // truncate one layer's weights: must fail at registration, not
        // mid-request in a pool worker
        weights.get_mut("c1").unwrap().w.pop();
        let eng = Arc::new(Engine::new(topo, weights));
        assert!(ModelRegistry::single(eng).is_err());
    }

    #[test]
    fn scratch_dims_cover_all_models() {
        let mut rng = Rng::new(4);
        let (t1, w1) = synth::tiny_model(&mut rng);
        let (t2, w2) = synth::bench_model(&mut rng);
        let e1 = Arc::new(Engine::new(t1, w1));
        let e2 = Arc::new(Engine::new(t2, w2));
        let (d1, d2) = (e1.scratch_dims(), e2.scratch_dims());
        let reg =
            ModelRegistry::new(vec![("tiny".into(), e1), ("bench".into(), e2)]).unwrap();
        let d = reg.scratch_dims();
        for (a, b) in [(d1, d), (d2, d)] {
            assert!(
                a.acts <= b.acts
                    && a.patches <= b.patches
                    && a.apanel <= b.apanel
                    && a.quant <= b.quant
            );
        }
        assert_eq!(d, d1.union(d2));
    }

    #[test]
    fn with_added_appends_a_fresh_slot() {
        let reg = ModelRegistry::new(vec![("a".into(), engine(1))]).unwrap();
        let reg2 = reg
            .with_added("b", engine(2), PolicyOverrides::default())
            .unwrap();
        assert_eq!(reg2.epoch(), 1);
        assert_eq!(reg2.len(), 2);
        assert_eq!(reg2.id_of("b"), Some(1));
        assert_eq!(reg2.get(1).unwrap().added_at_epoch, 1);
        // original snapshot is untouched (swap, not mutate)
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.epoch(), 0);
        // duplicate live name rejected
        assert!(reg2
            .with_added("a", engine(3), PolicyOverrides::default())
            .is_err());
        // invalid engine rejected before any slot is assigned
        let mut rng = Rng::new(9);
        let (topo, mut weights) = synth::tiny_model(&mut rng);
        weights.get_mut("c1").unwrap().w.pop();
        assert!(reg2
            .with_added("bad", Arc::new(Engine::new(topo, weights)), Default::default())
            .is_err());
    }

    #[test]
    fn with_removed_tombstones_the_id_forever() {
        let reg = ModelRegistry::new(vec![
            ("a".into(), engine(1)),
            ("b".into(), engine(2)),
        ])
        .unwrap();
        let reg2 = reg.with_removed("a").unwrap();
        assert_eq!(reg2.epoch(), 1);
        // the slot stays assigned but answers unknown
        assert_eq!(reg2.len(), 2);
        assert_eq!(reg2.live_len(), 1);
        assert!(reg2.get(0).is_none());
        assert!(reg2.default_entry().is_none());
        assert_eq!(reg2.id_of("a"), None);
        assert_eq!(reg2.id_of("b"), Some(1));
        // iter skips the tombstone
        assert_eq!(reg2.iter().map(|(i, _)| i).collect::<Vec<_>>(), vec![1]);
        // re-adding the name gets a NEW id; the old id stays dead
        let reg3 = reg2
            .with_added("a", engine(3), PolicyOverrides::default())
            .unwrap();
        assert_eq!(reg3.id_of("a"), Some(2));
        assert!(reg3.get(0).is_none());
        // unknown name / last live model rejected
        assert!(reg2.with_removed("zzz").is_err());
        assert!(reg2.with_removed("b").is_err());
    }

    #[test]
    fn with_policy_merges_single_keys() {
        let specs = vec![
            ModelSpec::parse("a=synth:tiny;weight=3;max_batch=8", None, None).unwrap(),
        ];
        let reg = ModelRegistry::from_specs(&specs, |_| unreachable!()).unwrap();
        let over = PolicyOverrides {
            weight: Some(5),
            ..Default::default()
        };
        let reg2 = reg.with_policy("a", &over).unwrap();
        assert_eq!(reg2.epoch(), 1);
        let p = &reg2.get(0).unwrap().policy;
        // retuned key replaced, untouched key kept
        assert_eq!(p.weight, Some(5));
        assert_eq!(p.max_batch, Some(8));
        // added_at_epoch survives a retune
        assert_eq!(reg2.get(0).unwrap().added_at_epoch, 0);
        assert!(reg.with_policy("nope", &over).is_err());
    }

    #[test]
    fn scratch_dims_grow_only_across_epochs() {
        let mut rng = Rng::new(4);
        let (t2, w2) = synth::bench_model(&mut rng);
        let big = Arc::new(Engine::new(t2, w2));
        let big_dims = big.scratch_dims();
        let reg = ModelRegistry::new(vec![("tiny".into(), engine(1))]).unwrap();
        let reg2 = reg
            .with_added("bench", big, PolicyOverrides::default())
            .unwrap();
        assert_eq!(reg2.scratch_dims(), reg.scratch_dims().union(big_dims));
        // removing the big model keeps the union: in-flight batches on
        // the old engine still fit, and scratch never shrinks mid-run
        let reg3 = reg2.with_removed("bench").unwrap();
        assert_eq!(reg3.scratch_dims(), reg2.scratch_dims());
    }

    #[test]
    fn reloaded_bumps_only_the_epoch() {
        let reg = ModelRegistry::new(vec![("a".into(), engine(1))]).unwrap();
        let reg2 = reg.reloaded();
        assert_eq!(reg2.epoch(), 1);
        assert_eq!(reg2.len(), 1);
        assert_eq!(reg2.get(0).unwrap().name, "a");
        assert_eq!(reg2.get(0).unwrap().added_at_epoch, 0);
    }
}
