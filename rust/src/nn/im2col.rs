//! im2col patch extraction, matching the JAX
//! `conv_general_dilated_patches` row ordering (channel-major:
//! row = c·k² + kh·k + kw; groups occupy contiguous row ranges).
//!
//! Layout: patches are stored **column-major per output pixel** — the
//! buffer is `(P, R)` row-major with P = ho·wo, so each output pixel's R
//! patch values are contiguous. This makes both the border quantization
//! (which operates on one im2col column = one VDP vector) and the GEMM
//! inner loop cache-friendly.
//!
//! `extract_fused` applies a column-quantization hook while the gathered
//! column is still hot in cache — the Figure 3 "fused" configuration; the
//! unfused path does a second pass over the full patch buffer.
//!
//! Both the gather and the GEMM come in `_range`/`_rows` forms that
//! operate on a sub-range of output pixels / output channels, so the
//! pool can shard ONE image's work across workers (intra-image
//! parallelism); the plain entry points cover the full range. Interior
//! pixels (every tap in-bounds) skip the per-element bounds checks and
//! copy whole k-wide rows (`kernels::gather_row`); the GEMM inner
//! product goes through the SIMD-dispatched `kernels::dot`.
//!
//! **Packed-panel GEMM.** The serving path no longer walks `dot` per
//! output row: weights are packed ONCE per engine (`pack_weights`, off
//! the serving path — `ModelRegistry` builds it at registration) into
//! B panels of ≤`NR` output channels, each panel stored as `KC`-element
//! K strips with the `nr` channel rows contiguous per strip; the im2col
//! patch buffer is repacked per image (`pack_patches`, a pure copy)
//! into the same strip layout per conv group; and `gemm_panels` walks
//! `MR x NR` register tiles over the strips via `kernels::gemm_tile_on`.
//! Panels never cross a conv-group boundary. In the default exact mode
//! the tile kernel's reduction order is identical to `kernels::dot`'s,
//! so the packed path is **bit-identical** to `gemm`/`gemm_rows` (which
//! remain as the reference the property tests compare against).
//!
//! Panel indexing: with `ocg = oc/groups` channels and
//! `ppg = ceil(ocg/NR)` panels per group, global panel `t` covers
//! channels `[panel_channel(t), panel_channel(t+1))` — a contiguous,
//! monotone map, so sharding the GEMM by panel ranges yields disjoint
//! output-channel row ranges exactly like `gemm_rows` sharding did.

use super::kernels;
use super::topology::LayerTopo;

/// Plain im2col: gather patches of `x` (C,H,W) into `out` (P·R).
pub fn extract(l: &LayerTopo, x: &[f32], out: &mut [f32]) {
    let (_, ho, wo) = l.out_chw;
    extract_range(l, x, out, 0, ho * wo, |_col| {});
}

/// im2col with a per-column hook applied while the column is hot.
pub fn extract_fused<F: FnMut(&mut [f32])>(l: &LayerTopo, x: &[f32], out: &mut [f32], hook: F) {
    let (_, ho, wo) = l.out_chw;
    extract_range(l, x, out, 0, ho * wo, hook);
}

/// Gather output pixels `[p0, p1)` (row-major over ho×wo), applying
/// `hook` to each finished column. `out` is ONLY this range's columns —
/// `(p1-p0)·R` f32s, i.e. `full[p0*R..p1*R]` — so parallel executors
/// hold genuinely disjoint `&mut` slices instead of aliasing views of
/// the whole buffer.
pub fn extract_range<F: FnMut(&mut [f32])>(
    l: &LayerTopo,
    x: &[f32],
    out: &mut [f32],
    p0: usize,
    p1: usize,
    mut hook: F,
) {
    let (c_in, h, w) = l.in_chw;
    let (_, ho, wo) = l.out_chw;
    let (k, s, p) = (l.k, l.stride, l.pad);
    let r = l.rows;
    debug_assert_eq!(x.len(), c_in * h * w);
    debug_assert_eq!(out.len(), (p1 - p0) * r);
    debug_assert!(p0 <= p1 && p1 <= ho * wo);
    let k2 = k * k;
    for pix in p0..p1 {
        let (oy, ox) = (pix / wo, pix % wo);
        let col = &mut out[(pix - p0) * r..(pix - p0 + 1) * r];
        let base_y = (oy * s) as isize - p as isize;
        let base_x = (ox * s) as isize - p as isize;
        // Interior fast path: every tap of the k×k window lands in
        // bounds, so each (c, ky) row is one contiguous k-wide copy.
        let interior = base_y >= 0
            && base_x >= 0
            && base_y as usize + k <= h
            && base_x as usize + k <= w;
        if interior {
            let (y0, x0) = (base_y as usize, base_x as usize);
            for c in 0..c_in {
                let plane = &x[c * h * w..(c + 1) * h * w];
                let dst = &mut col[c * k2..(c + 1) * k2];
                for ky in 0..k {
                    let src = &plane[(y0 + ky) * w + x0..(y0 + ky) * w + x0 + k];
                    kernels::gather_row(&mut dst[ky * k..(ky + 1) * k], src);
                }
            }
        } else {
            for c in 0..c_in {
                let plane = &x[c * h * w..(c + 1) * h * w];
                let dst = &mut col[c * k2..(c + 1) * k2];
                let mut i = 0;
                for ky in 0..k {
                    let yy = base_y + ky as isize;
                    if yy < 0 || yy >= h as isize {
                        for _ in 0..k {
                            dst[i] = 0.0;
                            i += 1;
                        }
                        continue;
                    }
                    let row = &plane[yy as usize * w..(yy as usize + 1) * w];
                    for kx in 0..k {
                        let xx = base_x + kx as isize;
                        dst[i] = if xx < 0 || xx >= w as isize {
                            0.0
                        } else {
                            row[xx as usize]
                        };
                        i += 1;
                    }
                }
            }
        }
        hook(col);
    }
}

/// GEMM over extracted patches: `out[o][p] = Σ_r w[o][r_g] · patches[p][r]`
/// with grouped row ranges, plus bias. `out` is (oc, P) row-major.
pub fn gemm(l: &LayerTopo, wts: &[f32], bias: &[f32], patches: &[f32], out: &mut [f32]) {
    gemm_rows(l, wts, bias, patches, out, 0, l.oc);
}

/// GEMM restricted to output channels `[o0, o1)`. `out` is ONLY this
/// range's rows — `(o1-o0)·P` f32s, i.e. `full[o0*P..o1*P]` — so
/// workers splitting one image's GEMM hold disjoint `&mut` slices
/// (`patches` is shared read-only). The inner product is the
/// SIMD-dispatched lane-blocked `kernels::dot` (every backend
/// bit-identical).
pub fn gemm_rows(
    l: &LayerTopo,
    wts: &[f32],
    bias: &[f32],
    patches: &[f32],
    out: &mut [f32],
    o0: usize,
    o1: usize,
) {
    let (_, ho, wo) = l.out_chw;
    let np = ho * wo;
    let r = l.rows;
    let rg = l.rows_per_group();
    let ocg = l.oc / l.groups;
    debug_assert_eq!(wts.len(), l.oc * rg);
    debug_assert_eq!(out.len(), (o1 - o0) * np);
    debug_assert!(o0 <= o1 && o1 <= l.oc);
    for o in o0..o1 {
        let g = o / ocg;
        let wrow = &wts[o * rg..(o + 1) * rg];
        let b = bias[o];
        let orow = &mut out[(o - o0) * np..(o - o0 + 1) * np];
        for (p, ov) in orow.iter_mut().enumerate() {
            let col = &patches[p * r + g * rg..p * r + (g + 1) * rg];
            *ov = kernels::dot(wrow, col) + b;
        }
    }
}

/// Weights packed into B-panel layout for the tiled GEMM (module docs).
/// Built once per engine at registration; `data` is exactly
/// `oc * rows_per_group` f32s — the panel covering channels
/// `[c0, c0+nr)` lives at `data[c0*rg..(c0+nr)*rg]`, laid out as KC
/// strips with the `nr` channel rows contiguous per strip.
#[derive(Debug, Clone)]
pub struct PackedGemm {
    pub data: Vec<f32>,
    /// K per group (`rows_per_group` at pack time).
    pub rg: usize,
    /// Output channels per group.
    pub ocg: usize,
    /// Panels per group (`ceil(ocg / NR)`).
    pub ppg: usize,
}

/// Number of B panels for `l` (`groups * ppg`); the panel index space
/// `[0, n_panels)` is what intra-image GEMM sharding chunks over.
pub fn n_panels(l: &LayerTopo) -> usize {
    let ocg = l.oc / l.groups;
    l.groups * ((ocg + kernels::NR - 1) / kernels::NR)
}

/// First output channel of panel `t`; `panel_channel(l, n_panels(l))`
/// is `oc`, so `[panel_channel(t0), panel_channel(t1))` is the channel
/// range a panel range covers.
pub fn panel_channel(l: &LayerTopo, t: usize) -> usize {
    let ocg = l.oc / l.groups;
    let ppg = (ocg + kernels::NR - 1) / kernels::NR;
    let (g, j) = (t / ppg, t % ppg);
    g * ocg + j * kernels::NR
}

/// Pack conv weights into B panels. O(oc·rg) copies, done once per
/// engine by `ModelRegistry` registration (or lazily on the first bare
/// `forward`), so the serving path never pays it.
pub fn pack_weights(l: &LayerTopo, wts: &[f32]) -> PackedGemm {
    let rg = l.rows_per_group();
    let ocg = l.oc / l.groups;
    let ppg = (ocg + kernels::NR - 1) / kernels::NR;
    debug_assert_eq!(wts.len(), l.oc * rg);
    let mut data = vec![0.0f32; l.oc * rg];
    for g in 0..l.groups {
        for j in 0..ppg {
            let c0 = g * ocg + j * kernels::NR;
            let nr = (ocg - j * kernels::NR).min(kernels::NR);
            let pbase = c0 * rg;
            let mut kbase = 0;
            while kbase < rg {
                let ls = (rg - kbase).min(kernels::KC);
                for ni in 0..nr {
                    let src = &wts[(c0 + ni) * rg + kbase..(c0 + ni) * rg + kbase + ls];
                    let dst = pbase + nr * kbase + ni * ls;
                    data[dst..dst + ls].copy_from_slice(src);
                }
                kbase += ls;
            }
        }
    }
    PackedGemm { data, rg, ocg, ppg }
}

/// Repack the im2col patch buffer into the A-panel scratch the tile
/// kernel reads: one block per conv group at `g*(np*rg)`, each block KC
/// strips of `np` row-contiguous segments (patch `p`'s slice of strip
/// `s` at `np*kbase + p*ls`). A pure copy — done serially by the
/// submitting worker, then shared read-only by every GEMM executor.
pub fn pack_patches(l: &LayerTopo, patches: &[f32], apanel: &mut [f32]) {
    let (_, ho, wo) = l.out_chw;
    let np = ho * wo;
    let r = l.rows;
    let rg = l.rows_per_group();
    debug_assert_eq!(patches.len(), np * r);
    debug_assert!(apanel.len() >= np * r);
    for g in 0..l.groups {
        let gbase = g * (np * rg);
        let mut kbase = 0;
        while kbase < rg {
            let ls = (rg - kbase).min(kernels::KC);
            let sbase = gbase + np * kbase;
            for p in 0..np {
                let src = &patches[p * r + g * rg + kbase..p * r + g * rg + kbase + ls];
                apanel[sbase + p * ls..sbase + (p + 1) * ls].copy_from_slice(src);
            }
            kbase += ls;
        }
    }
}

/// Tiled GEMM over B panels `[t0, t1)` against the packed-A scratch.
/// `out` is ONLY this range's channel rows —
/// `(panel_channel(t1) - panel_channel(t0)) * np` f32s — so panel-range
/// shards hold disjoint `&mut` slices like `gemm_rows` shards did.
/// Exact mode is bit-identical to `gemm_rows` over the same range.
#[allow(clippy::too_many_arguments)]
pub fn gemm_panels_on(
    backend: kernels::Backend,
    fast: kernels::FastMode,
    l: &LayerTopo,
    pg: &PackedGemm,
    bias: &[f32],
    apanel: &[f32],
    out: &mut [f32],
    t0: usize,
    t1: usize,
) {
    let (_, ho, wo) = l.out_chw;
    let np = ho * wo;
    let rg = pg.rg;
    let o_base = panel_channel(l, t0);
    debug_assert_eq!(out.len(), (panel_channel(l, t1) - o_base) * np);
    debug_assert!(t0 <= t1 && t1 <= n_panels(l));
    let mut sums = [0.0f32; kernels::MR * kernels::NR];
    for t in t0..t1 {
        let (g, j) = (t / pg.ppg, t % pg.ppg);
        let c0 = g * pg.ocg + j * kernels::NR;
        let nr = (pg.ocg - j * kernels::NR).min(kernels::NR);
        let panel = &pg.data[c0 * rg..(c0 + nr) * rg];
        let ablock = &apanel[g * (np * rg)..(g + 1) * (np * rg)];
        let mut m0 = 0;
        while m0 < np {
            let mr = (np - m0).min(kernels::MR);
            kernels::gemm_tile_on(backend, fast, ablock, np, m0, mr, panel, nr, rg, &mut sums);
            for mi in 0..mr {
                for ni in 0..nr {
                    out[(c0 + ni - o_base) * np + m0 + mi] = sums[mi * nr + ni] + bias[c0 + ni];
                }
            }
            m0 += mr;
        }
    }
}

/// `gemm_panels_on` with the process-wide backend and fast mode.
pub fn gemm_panels(
    l: &LayerTopo,
    pg: &PackedGemm,
    bias: &[f32],
    apanel: &[f32],
    out: &mut [f32],
    t0: usize,
    t1: usize,
) {
    gemm_panels_on(
        kernels::active(),
        kernels::fast_mode(),
        l,
        pg,
        bias,
        apanel,
        out,
        t0,
        t1,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::topology::LayerTopo;

    fn layer(ic: usize, oc: usize, k: usize, stride: usize, pad: usize, groups: usize, h: usize, w: usize) -> LayerTopo {
        let ho = (h + 2 * pad - k) / stride + 1;
        let wo = (w + 2 * pad - k) / stride + 1;
        LayerTopo {
            name: "t".into(),
            kind: "conv".into(),
            ic,
            oc,
            k,
            stride,
            pad,
            groups,
            relu: false,
            gap_input: false,
            rows: ic * k * k,
            in_chw: (ic, h, w),
            out_chw: (oc, ho, wo),
        }
    }

    /// Naive direct convolution for cross-checking.
    fn conv_naive(l: &LayerTopo, wts: &[f32], bias: &[f32], x: &[f32]) -> Vec<f32> {
        let (ic, h, w) = l.in_chw;
        let (oc, ho, wo) = l.out_chw;
        let icg = ic / l.groups;
        let ocg = oc / l.groups;
        let mut out = vec![0.0f32; oc * ho * wo];
        for o in 0..oc {
            let g = o / ocg;
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = bias[o];
                    for ci in 0..icg {
                        let c = g * icg + ci;
                        for ky in 0..l.k {
                            for kx in 0..l.k {
                                let yy = (oy * l.stride + ky) as isize - l.pad as isize;
                                let xx = (ox * l.stride + kx) as isize - l.pad as isize;
                                if yy < 0 || yy >= h as isize || xx < 0 || xx >= w as isize {
                                    continue;
                                }
                                let xv = x[c * h * w + yy as usize * w + xx as usize];
                                let wv = wts[o * icg * l.k * l.k + ci * l.k * l.k + ky * l.k + kx];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[o * ho * wo + oy * wo + ox] = acc;
                }
            }
        }
        out
    }

    fn check_layer(l: LayerTopo, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let (ic, h, w) = l.in_chw;
        let x: Vec<f32> = (0..ic * h * w).map(|_| rng.normal()).collect();
        let wts: Vec<f32> = (0..l.weight_elems()).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..l.oc).map(|_| rng.normal()).collect();
        let (_, ho, wo) = l.out_chw;
        let mut patches = vec![0.0f32; ho * wo * l.rows];
        extract(&l, &x, &mut patches);
        let mut out = vec![0.0f32; l.oc * ho * wo];
        gemm(&l, &wts, &bias, &patches, &mut out);
        let expect = conv_naive(&l, &wts, &bias, &x);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // range-sharded forms tile to exactly the full-range results
        let np = ho * wo;
        let mut patches2 = vec![0.0f32; np * l.rows];
        let mid = np / 3;
        let (pa, pb) = patches2.split_at_mut(mid * l.rows);
        extract_range(&l, &x, pa, 0, mid, |_| {});
        extract_range(&l, &x, pb, mid, np, |_| {});
        assert_eq!(patches, patches2, "extract_range tiles != extract");
        let mut out2 = vec![0.0f32; l.oc * np];
        let omid = l.oc / 2;
        let (oa, ob) = out2.split_at_mut(omid * np);
        gemm_rows(&l, &wts, &bias, &patches2, oa, 0, omid);
        gemm_rows(&l, &wts, &bias, &patches2, ob, omid, l.oc);
        assert_eq!(out, out2, "gemm_rows tiles != gemm");
        // packed-panel tiled GEMM is bit-identical to the dot-based
        // reference on every available backend (exact mode)
        let pg = pack_weights(&l, &wts);
        let nt = n_panels(&l);
        assert_eq!(panel_channel(&l, nt), l.oc);
        let mut ap = vec![0.0f32; np * l.rows];
        pack_patches(&l, &patches, &mut ap);
        for b in kernels::Backend::all() {
            if !b.available() {
                continue;
            }
            let mut out3 = vec![0.0f32; l.oc * np];
            gemm_panels_on(
                b,
                kernels::FastMode::Exact,
                &l,
                &pg,
                &bias,
                &ap,
                &mut out3,
                0,
                nt,
            );
            for (i, (a, c)) in out.iter().zip(&out3).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    c.to_bits(),
                    "packed GEMM != dot GEMM at {i} on {b:?}"
                );
            }
        }
        // panel-range shards tile to the same bits as the full range
        if nt >= 2 {
            let tmid = nt / 2;
            let o_mid = panel_channel(&l, tmid);
            let mut out4 = vec![0.0f32; l.oc * np];
            let (ta, tb) = out4.split_at_mut(o_mid * np);
            gemm_panels(&l, &pg, &bias, &ap, ta, 0, tmid);
            gemm_panels(&l, &pg, &bias, &ap, tb, tmid, nt);
            for (i, (a, c)) in out.iter().zip(&out4).enumerate() {
                assert_eq!(a.to_bits(), c.to_bits(), "panel shards != full at {i}");
            }
        }
    }

    #[test]
    fn conv_matches_naive_basic() {
        check_layer(layer(3, 5, 3, 1, 1, 1, 7, 7), 1);
    }

    #[test]
    fn conv_matches_naive_strided() {
        check_layer(layer(4, 6, 3, 2, 1, 1, 8, 8), 2);
    }

    #[test]
    fn conv_matches_naive_1x1() {
        check_layer(layer(8, 4, 1, 1, 0, 1, 6, 6), 3);
    }

    #[test]
    fn conv_matches_naive_grouped() {
        check_layer(layer(8, 8, 3, 1, 1, 4, 6, 6), 4);
    }

    #[test]
    fn conv_matches_naive_depthwise() {
        check_layer(layer(6, 6, 3, 2, 1, 6, 8, 8), 5);
    }

    #[test]
    fn fused_hook_sees_every_column() {
        let l = layer(2, 2, 3, 1, 1, 1, 4, 4);
        let x: Vec<f32> = (0..2 * 16).map(|i| i as f32).collect();
        let mut patches = vec![0.0f32; 16 * l.rows];
        let mut count = 0;
        extract_fused(&l, &x, &mut patches, |col| {
            assert_eq!(col.len(), l.rows);
            count += 1;
        });
        assert_eq!(count, 16);
    }

    #[test]
    fn interior_fast_path_matches_bounds_checked_gather() {
        // pad large enough that border pixels exercise the slow path and
        // central pixels the contiguous-copy path, on an asymmetric image
        let l = layer(3, 2, 3, 1, 2, 1, 6, 9);
        let (ic, h, w) = l.in_chw;
        let x: Vec<f32> = (0..ic * h * w).map(|i| (i as f32) * 0.37 - 11.0).collect();
        let (_, ho, wo) = l.out_chw;
        let mut got = vec![0.0f32; ho * wo * l.rows];
        extract(&l, &x, &mut got);
        // reference: force the bounds-checked path by re-deriving each
        // element independently
        for pix in 0..ho * wo {
            let (oy, ox) = (pix / wo, pix % wo);
            for c in 0..ic {
                for ky in 0..l.k {
                    for kx in 0..l.k {
                        let yy = (oy * l.stride + ky) as isize - l.pad as isize;
                        let xx = (ox * l.stride + kx) as isize - l.pad as isize;
                        let want = if yy < 0 || yy >= h as isize || xx < 0 || xx >= w as isize {
                            0.0
                        } else {
                            x[c * h * w + yy as usize * w + xx as usize]
                        };
                        let r = c * l.k * l.k + ky * l.k + kx;
                        assert_eq!(got[pix * l.rows + r], want, "pix {pix} row {r}");
                    }
                }
            }
        }
    }
}
