//! im2col patch extraction, matching the JAX
//! `conv_general_dilated_patches` row ordering (channel-major:
//! row = c·k² + kh·k + kw; groups occupy contiguous row ranges).
//!
//! Layout: patches are stored **column-major per output pixel** — the
//! buffer is `(P, R)` row-major with P = ho·wo, so each output pixel's R
//! patch values are contiguous. This makes both the border quantization
//! (which operates on one im2col column = one VDP vector) and the GEMM
//! inner loop cache-friendly.
//!
//! `extract_fused` applies a column-quantization hook while the gathered
//! column is still hot in cache — the Figure 3 "fused" configuration; the
//! unfused path does a second pass over the full patch buffer.

use super::topology::LayerTopo;

/// Plain im2col: gather patches of `x` (C,H,W) into `out` (P·R).
pub fn extract(l: &LayerTopo, x: &[f32], out: &mut [f32]) {
    extract_impl(l, x, out, |_col| {});
}

/// im2col with a per-column hook applied while the column is hot.
pub fn extract_fused<F: FnMut(&mut [f32])>(l: &LayerTopo, x: &[f32], out: &mut [f32], hook: F) {
    extract_impl(l, x, out, hook);
}

#[inline(always)]
fn extract_impl<F: FnMut(&mut [f32])>(l: &LayerTopo, x: &[f32], out: &mut [f32], mut hook: F) {
    let (c_in, h, w) = l.in_chw;
    let (_, ho, wo) = l.out_chw;
    let (k, s, p) = (l.k, l.stride, l.pad);
    let r = l.rows;
    debug_assert_eq!(x.len(), c_in * h * w);
    debug_assert_eq!(out.len(), ho * wo * r);
    let k2 = k * k;
    for oy in 0..ho {
        for ox in 0..wo {
            let col = &mut out[(oy * wo + ox) * r..(oy * wo + ox + 1) * r];
            let base_y = (oy * s) as isize - p as isize;
            let base_x = (ox * s) as isize - p as isize;
            for c in 0..c_in {
                let plane = &x[c * h * w..(c + 1) * h * w];
                let dst = &mut col[c * k2..(c + 1) * k2];
                let mut i = 0;
                for ky in 0..k {
                    let yy = base_y + ky as isize;
                    if yy < 0 || yy >= h as isize {
                        for _ in 0..k {
                            dst[i] = 0.0;
                            i += 1;
                        }
                        continue;
                    }
                    let row = &plane[yy as usize * w..(yy as usize + 1) * w];
                    for kx in 0..k {
                        let xx = base_x + kx as isize;
                        dst[i] = if xx < 0 || xx >= w as isize {
                            0.0
                        } else {
                            row[xx as usize]
                        };
                        i += 1;
                    }
                }
            }
            hook(col);
        }
    }
}

/// GEMM over extracted patches: `out[o][p] = Σ_r w[o][r_g] · patches[p][r]`
/// with grouped row ranges, plus bias. `out` is (oc, P) row-major.
pub fn gemm(l: &LayerTopo, wts: &[f32], bias: &[f32], patches: &[f32], out: &mut [f32]) {
    let (_, ho, wo) = l.out_chw;
    let np = ho * wo;
    let r = l.rows;
    let rg = l.rows_per_group();
    let ocg = l.oc / l.groups;
    debug_assert_eq!(wts.len(), l.oc * rg);
    debug_assert_eq!(out.len(), l.oc * np);
    for o in 0..l.oc {
        let g = o / ocg;
        let wrow = &wts[o * rg..(o + 1) * rg];
        let b = bias[o];
        let orow = &mut out[o * np..(o + 1) * np];
        for p in 0..np {
            let col = &patches[p * r + g * rg..p * r + (g + 1) * rg];
            let mut acc = 0.0f32;
            for (a, b_) in wrow.iter().zip(col) {
                acc += a * b_;
            }
            orow[p] = acc + b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::topology::LayerTopo;

    fn layer(ic: usize, oc: usize, k: usize, stride: usize, pad: usize, groups: usize, h: usize, w: usize) -> LayerTopo {
        let ho = (h + 2 * pad - k) / stride + 1;
        let wo = (w + 2 * pad - k) / stride + 1;
        LayerTopo {
            name: "t".into(),
            kind: "conv".into(),
            ic,
            oc,
            k,
            stride,
            pad,
            groups,
            relu: false,
            gap_input: false,
            rows: ic * k * k,
            in_chw: (ic, h, w),
            out_chw: (oc, ho, wo),
        }
    }

    /// Naive direct convolution for cross-checking.
    fn conv_naive(l: &LayerTopo, wts: &[f32], bias: &[f32], x: &[f32]) -> Vec<f32> {
        let (ic, h, w) = l.in_chw;
        let (oc, ho, wo) = l.out_chw;
        let icg = ic / l.groups;
        let ocg = oc / l.groups;
        let mut out = vec![0.0f32; oc * ho * wo];
        for o in 0..oc {
            let g = o / ocg;
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = bias[o];
                    for ci in 0..icg {
                        let c = g * icg + ci;
                        for ky in 0..l.k {
                            for kx in 0..l.k {
                                let yy = (oy * l.stride + ky) as isize - l.pad as isize;
                                let xx = (ox * l.stride + kx) as isize - l.pad as isize;
                                if yy < 0 || yy >= h as isize || xx < 0 || xx >= w as isize {
                                    continue;
                                }
                                let xv = x[c * h * w + yy as usize * w + xx as usize];
                                let wv = wts[o * icg * l.k * l.k + ci * l.k * l.k + ky * l.k + kx];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[o * ho * wo + oy * wo + ox] = acc;
                }
            }
        }
        out
    }

    fn check_layer(l: LayerTopo, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let (ic, h, w) = l.in_chw;
        let x: Vec<f32> = (0..ic * h * w).map(|_| rng.normal()).collect();
        let wts: Vec<f32> = (0..l.weight_elems()).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..l.oc).map(|_| rng.normal()).collect();
        let (_, ho, wo) = l.out_chw;
        let mut patches = vec![0.0f32; ho * wo * l.rows];
        extract(&l, &x, &mut patches);
        let mut out = vec![0.0f32; l.oc * ho * wo];
        gemm(&l, &wts, &bias, &patches, &mut out);
        let expect = conv_naive(&l, &wts, &bias, &x);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn conv_matches_naive_basic() {
        check_layer(layer(3, 5, 3, 1, 1, 1, 7, 7), 1);
    }

    #[test]
    fn conv_matches_naive_strided() {
        check_layer(layer(4, 6, 3, 2, 1, 1, 8, 8), 2);
    }

    #[test]
    fn conv_matches_naive_1x1() {
        check_layer(layer(8, 4, 1, 1, 0, 1, 6, 6), 3);
    }

    #[test]
    fn conv_matches_naive_grouped() {
        check_layer(layer(8, 8, 3, 1, 1, 4, 6, 6), 4);
    }

    #[test]
    fn conv_matches_naive_depthwise() {
        check_layer(layer(6, 6, 3, 2, 1, 6, 8, 8), 5);
    }

    #[test]
    fn fused_hook_sees_every_column() {
        let l = layer(2, 2, 3, 1, 1, 1, 4, 4);
        let x: Vec<f32> = (0..2 * 16).map(|i| i as f32).collect();
        let mut patches = vec![0.0f32; 16 * l.rows];
        let mut count = 0;
        extract_fused(&l, &x, &mut patches, |col| {
            assert_eq!(col.len(), l.rows);
            count += 1;
        });
        assert_eq!(count, 16);
    }
}
