//! im2col patch extraction, matching the JAX
//! `conv_general_dilated_patches` row ordering (channel-major:
//! row = c·k² + kh·k + kw; groups occupy contiguous row ranges).
//!
//! Layout: patches are stored **column-major per output pixel** — the
//! buffer is `(P, R)` row-major with P = ho·wo, so each output pixel's R
//! patch values are contiguous. This makes both the border quantization
//! (which operates on one im2col column = one VDP vector) and the GEMM
//! inner loop cache-friendly.
//!
//! `extract_fused` applies a column-quantization hook while the gathered
//! column is still hot in cache — the Figure 3 "fused" configuration; the
//! unfused path does a second pass over the full patch buffer.
//!
//! Both the gather and the GEMM come in `_range`/`_rows` forms that
//! operate on a sub-range of output pixels / output channels, so the
//! pool can shard ONE image's work across workers (intra-image
//! parallelism); the plain entry points cover the full range. Interior
//! pixels (every tap in-bounds) skip the per-element bounds checks and
//! copy whole k-wide rows (`kernels::gather_row`); the GEMM inner
//! product goes through the SIMD-dispatched `kernels::dot`.

use super::kernels;
use super::topology::LayerTopo;

/// Plain im2col: gather patches of `x` (C,H,W) into `out` (P·R).
pub fn extract(l: &LayerTopo, x: &[f32], out: &mut [f32]) {
    let (_, ho, wo) = l.out_chw;
    extract_range(l, x, out, 0, ho * wo, |_col| {});
}

/// im2col with a per-column hook applied while the column is hot.
pub fn extract_fused<F: FnMut(&mut [f32])>(l: &LayerTopo, x: &[f32], out: &mut [f32], hook: F) {
    let (_, ho, wo) = l.out_chw;
    extract_range(l, x, out, 0, ho * wo, hook);
}

/// Gather output pixels `[p0, p1)` (row-major over ho×wo), applying
/// `hook` to each finished column. `out` is ONLY this range's columns —
/// `(p1-p0)·R` f32s, i.e. `full[p0*R..p1*R]` — so parallel executors
/// hold genuinely disjoint `&mut` slices instead of aliasing views of
/// the whole buffer.
pub fn extract_range<F: FnMut(&mut [f32])>(
    l: &LayerTopo,
    x: &[f32],
    out: &mut [f32],
    p0: usize,
    p1: usize,
    mut hook: F,
) {
    let (c_in, h, w) = l.in_chw;
    let (_, ho, wo) = l.out_chw;
    let (k, s, p) = (l.k, l.stride, l.pad);
    let r = l.rows;
    debug_assert_eq!(x.len(), c_in * h * w);
    debug_assert_eq!(out.len(), (p1 - p0) * r);
    debug_assert!(p0 <= p1 && p1 <= ho * wo);
    let k2 = k * k;
    for pix in p0..p1 {
        let (oy, ox) = (pix / wo, pix % wo);
        let col = &mut out[(pix - p0) * r..(pix - p0 + 1) * r];
        let base_y = (oy * s) as isize - p as isize;
        let base_x = (ox * s) as isize - p as isize;
        // Interior fast path: every tap of the k×k window lands in
        // bounds, so each (c, ky) row is one contiguous k-wide copy.
        let interior = base_y >= 0
            && base_x >= 0
            && base_y as usize + k <= h
            && base_x as usize + k <= w;
        if interior {
            let (y0, x0) = (base_y as usize, base_x as usize);
            for c in 0..c_in {
                let plane = &x[c * h * w..(c + 1) * h * w];
                let dst = &mut col[c * k2..(c + 1) * k2];
                for ky in 0..k {
                    let src = &plane[(y0 + ky) * w + x0..(y0 + ky) * w + x0 + k];
                    kernels::gather_row(&mut dst[ky * k..(ky + 1) * k], src);
                }
            }
        } else {
            for c in 0..c_in {
                let plane = &x[c * h * w..(c + 1) * h * w];
                let dst = &mut col[c * k2..(c + 1) * k2];
                let mut i = 0;
                for ky in 0..k {
                    let yy = base_y + ky as isize;
                    if yy < 0 || yy >= h as isize {
                        for _ in 0..k {
                            dst[i] = 0.0;
                            i += 1;
                        }
                        continue;
                    }
                    let row = &plane[yy as usize * w..(yy as usize + 1) * w];
                    for kx in 0..k {
                        let xx = base_x + kx as isize;
                        dst[i] = if xx < 0 || xx >= w as isize {
                            0.0
                        } else {
                            row[xx as usize]
                        };
                        i += 1;
                    }
                }
            }
        }
        hook(col);
    }
}

/// GEMM over extracted patches: `out[o][p] = Σ_r w[o][r_g] · patches[p][r]`
/// with grouped row ranges, plus bias. `out` is (oc, P) row-major.
pub fn gemm(l: &LayerTopo, wts: &[f32], bias: &[f32], patches: &[f32], out: &mut [f32]) {
    gemm_rows(l, wts, bias, patches, out, 0, l.oc);
}

/// GEMM restricted to output channels `[o0, o1)`. `out` is ONLY this
/// range's rows — `(o1-o0)·P` f32s, i.e. `full[o0*P..o1*P]` — so
/// workers splitting one image's GEMM hold disjoint `&mut` slices
/// (`patches` is shared read-only). The inner product is the
/// SIMD-dispatched lane-blocked `kernels::dot` (every backend
/// bit-identical).
pub fn gemm_rows(
    l: &LayerTopo,
    wts: &[f32],
    bias: &[f32],
    patches: &[f32],
    out: &mut [f32],
    o0: usize,
    o1: usize,
) {
    let (_, ho, wo) = l.out_chw;
    let np = ho * wo;
    let r = l.rows;
    let rg = l.rows_per_group();
    let ocg = l.oc / l.groups;
    debug_assert_eq!(wts.len(), l.oc * rg);
    debug_assert_eq!(out.len(), (o1 - o0) * np);
    debug_assert!(o0 <= o1 && o1 <= l.oc);
    for o in o0..o1 {
        let g = o / ocg;
        let wrow = &wts[o * rg..(o + 1) * rg];
        let b = bias[o];
        let orow = &mut out[(o - o0) * np..(o - o0 + 1) * np];
        for (p, ov) in orow.iter_mut().enumerate() {
            let col = &patches[p * r + g * rg..p * r + (g + 1) * rg];
            *ov = kernels::dot(wrow, col) + b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::topology::LayerTopo;

    fn layer(ic: usize, oc: usize, k: usize, stride: usize, pad: usize, groups: usize, h: usize, w: usize) -> LayerTopo {
        let ho = (h + 2 * pad - k) / stride + 1;
        let wo = (w + 2 * pad - k) / stride + 1;
        LayerTopo {
            name: "t".into(),
            kind: "conv".into(),
            ic,
            oc,
            k,
            stride,
            pad,
            groups,
            relu: false,
            gap_input: false,
            rows: ic * k * k,
            in_chw: (ic, h, w),
            out_chw: (oc, ho, wo),
        }
    }

    /// Naive direct convolution for cross-checking.
    fn conv_naive(l: &LayerTopo, wts: &[f32], bias: &[f32], x: &[f32]) -> Vec<f32> {
        let (ic, h, w) = l.in_chw;
        let (oc, ho, wo) = l.out_chw;
        let icg = ic / l.groups;
        let ocg = oc / l.groups;
        let mut out = vec![0.0f32; oc * ho * wo];
        for o in 0..oc {
            let g = o / ocg;
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = bias[o];
                    for ci in 0..icg {
                        let c = g * icg + ci;
                        for ky in 0..l.k {
                            for kx in 0..l.k {
                                let yy = (oy * l.stride + ky) as isize - l.pad as isize;
                                let xx = (ox * l.stride + kx) as isize - l.pad as isize;
                                if yy < 0 || yy >= h as isize || xx < 0 || xx >= w as isize {
                                    continue;
                                }
                                let xv = x[c * h * w + yy as usize * w + xx as usize];
                                let wv = wts[o * icg * l.k * l.k + ci * l.k * l.k + ky * l.k + kx];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[o * ho * wo + oy * wo + ox] = acc;
                }
            }
        }
        out
    }

    fn check_layer(l: LayerTopo, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let (ic, h, w) = l.in_chw;
        let x: Vec<f32> = (0..ic * h * w).map(|_| rng.normal()).collect();
        let wts: Vec<f32> = (0..l.weight_elems()).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..l.oc).map(|_| rng.normal()).collect();
        let (_, ho, wo) = l.out_chw;
        let mut patches = vec![0.0f32; ho * wo * l.rows];
        extract(&l, &x, &mut patches);
        let mut out = vec![0.0f32; l.oc * ho * wo];
        gemm(&l, &wts, &bias, &patches, &mut out);
        let expect = conv_naive(&l, &wts, &bias, &x);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // range-sharded forms tile to exactly the full-range results
        let np = ho * wo;
        let mut patches2 = vec![0.0f32; np * l.rows];
        let mid = np / 3;
        let (pa, pb) = patches2.split_at_mut(mid * l.rows);
        extract_range(&l, &x, pa, 0, mid, |_| {});
        extract_range(&l, &x, pb, mid, np, |_| {});
        assert_eq!(patches, patches2, "extract_range tiles != extract");
        let mut out2 = vec![0.0f32; l.oc * np];
        let omid = l.oc / 2;
        let (oa, ob) = out2.split_at_mut(omid * np);
        gemm_rows(&l, &wts, &bias, &patches2, oa, 0, omid);
        gemm_rows(&l, &wts, &bias, &patches2, ob, omid, l.oc);
        assert_eq!(out, out2, "gemm_rows tiles != gemm");
    }

    #[test]
    fn conv_matches_naive_basic() {
        check_layer(layer(3, 5, 3, 1, 1, 1, 7, 7), 1);
    }

    #[test]
    fn conv_matches_naive_strided() {
        check_layer(layer(4, 6, 3, 2, 1, 1, 8, 8), 2);
    }

    #[test]
    fn conv_matches_naive_1x1() {
        check_layer(layer(8, 4, 1, 1, 0, 1, 6, 6), 3);
    }

    #[test]
    fn conv_matches_naive_grouped() {
        check_layer(layer(8, 8, 3, 1, 1, 4, 6, 6), 4);
    }

    #[test]
    fn conv_matches_naive_depthwise() {
        check_layer(layer(6, 6, 3, 2, 1, 6, 8, 8), 5);
    }

    #[test]
    fn fused_hook_sees_every_column() {
        let l = layer(2, 2, 3, 1, 1, 1, 4, 4);
        let x: Vec<f32> = (0..2 * 16).map(|i| i as f32).collect();
        let mut patches = vec![0.0f32; 16 * l.rows];
        let mut count = 0;
        extract_fused(&l, &x, &mut patches, |col| {
            assert_eq!(col.len(), l.rows);
            count += 1;
        });
        assert_eq!(count, 16);
    }

    #[test]
    fn interior_fast_path_matches_bounds_checked_gather() {
        // pad large enough that border pixels exercise the slow path and
        // central pixels the contiguous-copy path, on an asymmetric image
        let l = layer(3, 2, 3, 1, 2, 1, 6, 9);
        let (ic, h, w) = l.in_chw;
        let x: Vec<f32> = (0..ic * h * w).map(|i| (i as f32) * 0.37 - 11.0).collect();
        let (_, ho, wo) = l.out_chw;
        let mut got = vec![0.0f32; ho * wo * l.rows];
        extract(&l, &x, &mut got);
        // reference: force the bounds-checked path by re-deriving each
        // element independently
        for pix in 0..ho * wo {
            let (oy, ox) = (pix / wo, pix % wo);
            for c in 0..ic {
                for ky in 0..l.k {
                    for kx in 0..l.k {
                        let yy = (oy * l.stride + ky) as isize - l.pad as isize;
                        let xx = (ox * l.stride + kx) as isize - l.pad as isize;
                        let want = if yy < 0 || yy >= h as isize || xx < 0 || xx >= w as isize {
                            0.0
                        } else {
                            x[c * h * w + yy as usize * w + xx as usize]
                        };
                        let r = c * l.k * l.k + ky * l.k + kx;
                        assert_eq!(got[pix * l.rows + r], want, "pix {pix} row {r}");
                    }
                }
            }
        }
    }
}
