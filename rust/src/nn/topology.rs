//! Model topology, mirrored from the manifest's `meta.models` section
//! (produced by `python/compile/aot.py::model_topology_meta`).

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// One matmul-bearing layer (conv as im2col×matmul, or fc).
#[derive(Debug, Clone)]
pub struct LayerTopo {
    pub name: String,
    pub kind: String, // "conv" | "fc"
    pub ic: usize,
    pub oc: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
    pub relu: bool,
    pub gap_input: bool,
    /// im2col rows R = ic·k².
    pub rows: usize,
    /// Input (C, H, W).
    pub in_chw: (usize, usize, usize),
    /// Output (C, H, W).
    pub out_chw: (usize, usize, usize),
}

impl LayerTopo {
    pub fn k2(&self) -> usize {
        if self.kind == "fc" {
            1
        } else {
            self.k * self.k
        }
    }

    pub fn rows_per_group(&self) -> usize {
        (self.ic / self.groups) * self.k2()
    }

    /// Weight matrix shape (oc, rows_per_group).
    pub fn weight_elems(&self) -> usize {
        self.oc * self.rows_per_group()
    }

    fn from_json(j: &Json) -> Result<LayerTopo> {
        let us = |k: &str| -> Result<usize> {
            j.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow!("layer field {k} not a number"))
        };
        let chw = |k: &str| -> Result<(usize, usize, usize)> {
            let v = j.req(k)?.as_i64_vec()?;
            Ok((v[0] as usize, v[1] as usize, v[2] as usize))
        };
        Ok(LayerTopo {
            name: j
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow!("layer name"))?
                .to_string(),
            kind: j
                .req("kind")?
                .as_str()
                .ok_or_else(|| anyhow!("layer kind"))?
                .to_string(),
            ic: us("ic")?,
            oc: us("oc")?,
            k: us("k")?,
            stride: us("stride")?,
            pad: us("pad")?,
            groups: us("groups")?,
            relu: j.req("relu")?.as_bool().unwrap_or(false),
            gap_input: j.req("gap_input")?.as_bool().unwrap_or(false),
            rows: us("rows")?,
            in_chw: chw("in_chw")?,
            out_chw: chw("out_chw")?,
        })
    }
}

/// A reconstruction/wiring block.
#[derive(Debug, Clone)]
pub struct BlockTopo {
    pub name: String,
    pub residual: bool,
    /// Name of the skip-path 1×1 projection, if any (listed in `layers`).
    pub downsample: Option<String>,
    /// Main-path layers in order, downsample (if any) last.
    pub layers: Vec<LayerTopo>,
}

impl BlockTopo {
    /// Main-path layers (excluding the downsample projection).
    pub fn main_layers(&self) -> impl Iterator<Item = &LayerTopo> {
        let ds = self.downsample.clone();
        self.layers
            .iter()
            .filter(move |l| Some(&l.name) != ds.as_ref())
    }

    pub fn downsample_layer(&self) -> Option<&LayerTopo> {
        let ds = self.downsample.as_ref()?;
        self.layers.iter().find(|l| &l.name == ds)
    }
}

/// A whole model.
#[derive(Debug, Clone)]
pub struct ModelTopo {
    pub name: String,
    pub in_c: usize,
    pub in_hw: (usize, usize),
    pub n_classes: usize,
    pub blocks: Vec<BlockTopo>,
}

impl ModelTopo {
    pub fn from_json(j: &Json) -> Result<ModelTopo> {
        let blocks = j
            .req("blocks")?
            .as_arr()
            .ok_or_else(|| anyhow!("blocks not an array"))?
            .iter()
            .map(|b| {
                Ok(BlockTopo {
                    name: b
                        .req("name")?
                        .as_str()
                        .ok_or_else(|| anyhow!("block name"))?
                        .to_string(),
                    residual: b.req("residual")?.as_bool().unwrap_or(false),
                    downsample: b
                        .get("downsample")
                        .and_then(|d| d.as_str())
                        .map(str::to_string),
                    layers: b
                        .req("layers")?
                        .as_arr()
                        .ok_or_else(|| anyhow!("layers not an array"))?
                        .iter()
                        .map(LayerTopo::from_json)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let hw = j.req("in_hw")?.as_i64_vec()?;
        Ok(ModelTopo {
            name: j
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow!("model name"))?
                .to_string(),
            in_c: j.req("in_c")?.as_usize().ok_or_else(|| anyhow!("in_c"))?,
            in_hw: (hw[0] as usize, hw[1] as usize),
            n_classes: j
                .req("n_classes")?
                .as_usize()
                .ok_or_else(|| anyhow!("n_classes"))?,
            blocks,
        })
    }

    /// All layers in execution order (downsamples included, after their
    /// block's main path — matching `ModelDef.all_layers()` in python).
    pub fn all_layers(&self) -> Vec<&LayerTopo> {
        let mut out = Vec::new();
        for b in &self.blocks {
            out.extend(b.layers.iter());
        }
        out
    }

    pub fn layer(&self, name: &str) -> Result<&LayerTopo> {
        self.all_layers()
            .into_iter()
            .find(|l| l.name == name)
            .ok_or_else(|| anyhow!("layer {name:?} not in model {}", self.name))
    }

    /// First / last layer names (kept at 8 bits per the paper).
    pub fn first_layer(&self) -> &str {
        &self.blocks[0].layers[0].name
    }

    pub fn last_layer(&self) -> &str {
        let b = self.blocks.last().unwrap();
        &b.layers.last().unwrap().name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "tiny", "in_c": 3, "in_hw": [8, 8], "n_classes": 4,
      "blocks": [
        {"name": "stem", "residual": false, "downsample": null, "layers": [
          {"name": "stem_c", "kind": "conv", "ic": 3, "oc": 8, "k": 3,
           "stride": 1, "pad": 1, "groups": 1, "relu": true,
           "gap_input": false, "rows": 27, "in_chw": [3, 8, 8],
           "out_chw": [8, 8, 8]}]},
        {"name": "b1", "residual": true, "downsample": "b1_ds", "layers": [
          {"name": "b1_c1", "kind": "conv", "ic": 8, "oc": 16, "k": 3,
           "stride": 2, "pad": 1, "groups": 1, "relu": true,
           "gap_input": false, "rows": 72, "in_chw": [8, 8, 8],
           "out_chw": [16, 4, 4]},
          {"name": "b1_ds", "kind": "conv", "ic": 8, "oc": 16, "k": 1,
           "stride": 2, "pad": 0, "groups": 1, "relu": false,
           "gap_input": false, "rows": 8, "in_chw": [8, 8, 8],
           "out_chw": [16, 4, 4]}]}
      ]}"#;

    #[test]
    fn parse_topology() {
        let m = ModelTopo::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.blocks.len(), 2);
        assert_eq!(m.first_layer(), "stem_c");
        assert_eq!(m.last_layer(), "b1_ds");
        let b1 = &m.blocks[1];
        assert_eq!(b1.main_layers().count(), 1);
        assert_eq!(b1.downsample_layer().unwrap().name, "b1_ds");
        let l = m.layer("b1_c1").unwrap();
        assert_eq!(l.rows, 72);
        assert_eq!(l.k2(), 9);
        assert_eq!(l.weight_elems(), 16 * 72);
    }
}
