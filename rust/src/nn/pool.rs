//! Fixed worker thread-pool for batched inference.
//!
//! The pool is **model-agnostic**: each job (shard) carries the
//! `Arc<Engine>` it runs against, so one pool serves every model in a
//! [`crate::nn::registry::ModelRegistry`] without duplicating worker
//! threads. A batch of images is sharded into contiguous index ranges,
//! one per worker. Each worker is a long-lived thread owning one
//! [`EngineScratch`]; the scratch is model-agnostic too (grow-only
//! buffers, pre-sized to the max dims passed at construction), so after
//! warm-up the per-image hot loop performs no allocation even when
//! consecutive shards come from models of different shapes.
//!
//! Submission is **scheduler-driven**: [`InferencePool::submit`] is
//! non-blocking — it shards the batch, tags every shard with its wire
//! model id (per-model executed-image accounting lives here, where the
//! work actually runs), and invokes a completion callback from the last
//! finishing worker. This lets ONE fair-scheduler thread keep every
//! model's admissions flowing without blocking on any single batch (see
//! [`crate::server::sched`]). [`InferencePool::classify_flat`] is the
//! blocking wrapper (submit + wait) used by benches, tests, and
//! anything without a scheduler.
//!
//! Determinism: every image's forward pass is independent and the
//! per-image code path is exactly [`Engine::classify_scratch`] — the
//! same path the sequential [`Engine::classify_batch`] uses — so pooled
//! results are bit-identical to sequential results for any worker count,
//! any shard split, and any interleaving of models. The pool property
//! tests pin this down.
//!
//! Built on `std` only (rayon/crossbeam are unavailable offline): jobs
//! flow through an `mpsc` channel shared by workers behind a mutex.
//! The channel is FIFO, so the order batches are submitted in is the
//! order workers start them in — the fair scheduler's weighted
//! interleaving survives all the way to the CPUs.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Result};

use super::engine::{Engine, EngineScratch, IntraOp, ScratchDims};
use super::registry::ModelRegistry;

/// Completion callback for one submitted batch: predicted classes in
/// image order, or the first shard error. Invoked exactly once, from
/// the worker that finishes the batch's last shard.
pub type BatchDone = Box<dyn FnOnce(Result<Vec<usize>, String>) + Send>;

/// Shared state of one in-flight batch, assembled by its shards.
struct BatchState {
    /// Predictions in image order; shards fill disjoint ranges.
    preds: Mutex<Vec<usize>>,
    /// First shard error, if any (the whole batch fails).
    err: Mutex<Option<String>>,
    /// Shards still running; the worker that drops this to zero calls
    /// `done`.
    remaining: AtomicUsize,
    done: Mutex<Option<BatchDone>>,
}

impl BatchState {
    /// Record one finished shard; the last shard in resolves the batch.
    fn complete(&self, start: usize, result: Result<Vec<usize>, String>) {
        match result {
            Ok(p) => {
                let mut preds = self.preds.lock().unwrap();
                preds[start..start + p.len()].copy_from_slice(&p);
            }
            Err(e) => {
                let mut err = self.err.lock().unwrap();
                err.get_or_insert(e);
            }
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let done = self.done.lock().unwrap().take();
            if let Some(done) = done {
                let result = match self.err.lock().unwrap().take() {
                    Some(e) => Err(e),
                    None => Ok(std::mem::take(&mut *self.preds.lock().unwrap())),
                };
                done(result);
            }
        }
    }
}

/// Intra-image parallelism configuration for a pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IntraCfg {
    /// Chunks a big conv layer's gather/GEMM phases split into (gather
    /// chunks are output-pixel ranges; GEMM chunks are whole B-panel
    /// tile-strip ranges, so no register tile splits across executors).
    /// 0 = auto (one chunk per worker); 1 effectively disables sharding.
    pub split: usize,
    /// Minimum patch-buffer size (P·R f32 elements) before a layer is
    /// sharded — below this the fan-out costs more than it buys.
    pub min_elems: usize,
}

/// Default work threshold for intra-image sharding (P·R elements).
pub const INTRA_MIN_ELEMS: usize = 32 * 1024;

impl Default for IntraCfg {
    fn default() -> Self {
        IntraCfg {
            split: 0,
            min_elems: INTRA_MIN_ELEMS,
        }
    }
}

/// Handle pool workers carry (via their [`EngineScratch`]) for
/// publishing intra-image helper jobs back onto the shared job channel.
#[derive(Debug, Clone)]
pub(crate) struct IntraCtx {
    tx: Sender<Job>,
    /// Chunk count per parallel phase (resolved: never 0).
    pub(crate) split: usize,
    /// Work threshold (P·R elements) below which layers stay serial.
    pub(crate) min_elems: usize,
}

impl IntraCtx {
    /// Publish a task for `chunks` chunks: `chunks - 1` helper jobs go
    /// onto the channel (idle workers steal them; busy pools simply
    /// leave them for the submitter), and the returned task is what the
    /// submitter drives to completion via `execute` + [`IntraWait`].
    pub(crate) fn spawn(&self, op: IntraOp, chunks: usize) -> Arc<IntraTask> {
        let task = Arc::new(IntraTask {
            op,
            chunks,
            cursor: AtomicUsize::new(0),
            completed: Mutex::new(0),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        for _ in 1..chunks {
            // A closed channel (pool dropping) just means no helpers;
            // the submitter still runs every chunk itself.
            if self.tx.send(Job::Intra(task.clone())).is_err() {
                break;
            }
        }
        task
    }
}

/// One intra-image parallel phase: a chunked op plus claim/complete
/// bookkeeping. The claim cursor only moves forward, so the submitter
/// and any number of helpers (even ones arriving after the phase ended)
/// coordinate without ever blocking each other: late helpers see an
/// exhausted cursor and return without touching the op.
pub(crate) struct IntraTask {
    op: IntraOp,
    chunks: usize,
    /// Next unclaimed chunk index (monotonic; >= chunks means done).
    cursor: AtomicUsize,
    /// Chunks fully executed (guarded for the completion condvar).
    completed: Mutex<usize>,
    cv: Condvar,
    /// Set when any executor panicked mid-chunk (its output range is
    /// garbage, so the submitter must fail the image).
    panicked: AtomicBool,
}

impl IntraTask {
    /// Claim and run chunks until none remain. Both the submitter and
    /// helpers call this; `quant` is the executor's own border scratch.
    /// A panicking chunk still counts as completed (via the drop guard)
    /// and flags the task, so the submitter can never deadlock on it.
    pub(crate) fn execute(&self, quant: &mut Vec<f32>) {
        loop {
            let ci = self.cursor.fetch_add(1, Ordering::AcqRel);
            if ci >= self.chunks {
                return;
            }
            let guard = ChunkGuard { task: self };
            self.op.run_chunk(ci, self.chunks, quant);
            drop(guard);
        }
    }

    /// Quiesce: stop further claims and wait until every chunk that WAS
    /// claimed has completed. Returns whether any executor panicked.
    /// After this returns, no helper will ever dereference the op's
    /// pointers again (unclaimed chunks are abandoned, which only
    /// happens when the submitter is already failing the image).
    fn finish(&self) -> bool {
        let claimed = self.cursor.swap(self.chunks, Ordering::AcqRel).min(self.chunks);
        let mut done = self.completed.lock().unwrap();
        while *done < claimed {
            done = self.cv.wait(done).unwrap();
        }
        self.panicked.load(Ordering::Acquire)
    }
}

/// Marks a claimed chunk completed even if `run_chunk` unwinds, so
/// `finish` never waits forever; a panicking executor also poisons the
/// task.
struct ChunkGuard<'a> {
    task: &'a IntraTask,
}

impl Drop for ChunkGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.task.panicked.store(true, Ordering::Release);
        }
        let mut done = self.task.completed.lock().unwrap();
        *done += 1;
        self.task.cv.notify_all();
    }
}

/// Submitter-side guard around a phase: guarantees `finish` runs even
/// when the submitting thread itself unwinds mid-phase (helpers must be
/// quiesced before the buffers behind the op's pointers are reused).
pub(crate) struct IntraWait<'a> {
    task: &'a IntraTask,
    finished: bool,
}

impl<'a> IntraWait<'a> {
    pub(crate) fn new(task: &'a IntraTask) -> Self {
        IntraWait {
            task,
            finished: false,
        }
    }

    /// Normal-path completion; returns whether any executor panicked.
    pub(crate) fn finish(mut self) -> bool {
        self.finished = true;
        self.task.finish()
    }
}

impl Drop for IntraWait<'_> {
    fn drop(&mut self) {
        if !self.finished {
            self.task.finish();
        }
    }
}

/// A unit of work on the shared channel.
enum Job {
    /// A contiguous image range of a batch.
    Shard(Shard),
    /// Helper work for one image's current conv phase.
    Intra(Arc<IntraTask>),
    /// Shutdown sentinel: workers hold `IntraCtx` sender clones, so the
    /// channel never disconnects by itself — Drop sends one `Exit` per
    /// worker instead (FIFO: queued shards drain first).
    Exit,
}

/// One contiguous shard of a batch, dispatched to a single worker.
struct Shard {
    /// The engine this shard runs against (jobs carry their model; the
    /// pool owns none).
    engine: Arc<Engine>,
    /// Wire model id, for per-model executed-image accounting.
    model_id: u16,
    /// The whole batch, flattened (n · img_elems f32s), shared by ref-count.
    images: Arc<Vec<f32>>,
    img_elems: usize,
    /// Image index range [start, end) this worker classifies.
    start: usize,
    end: usize,
    batch: Arc<BatchState>,
}

/// Fixed-size, model-agnostic inference thread-pool.
pub struct InferencePool {
    workers: usize,
    /// Job channel; `None` once shutdown has begun (Drop).
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// Images successfully executed, by model id. Ids outside the
    /// accounting range are counted nowhere (reads return 0 for them
    /// too — writes and reads agree).
    executed: Arc<Vec<AtomicU64>>,
}

impl InferencePool {
    /// Spawn `workers` (min 1) threads, each with its own scratch.
    /// Intra-image sharding is on by default (auto split, default
    /// threshold); use [`InferencePool::with_intra`] to tune or disable.
    pub fn new(workers: usize) -> Self {
        Self::with_scratch_dims(workers, ScratchDims::default())
    }

    /// Spawn workers whose scratch is pre-reserved for `dims` (use the
    /// registry's max-dims union so the largest model's first image
    /// doesn't pay reallocation). Accounting has a single model slot;
    /// use [`InferencePool::for_registry`] for multi-model serving.
    pub fn with_scratch_dims(workers: usize, dims: ScratchDims) -> Self {
        Self::build(workers, dims, 1, Some(IntraCfg::default()))
    }

    /// Full-control constructor: `intra = None` disables intra-image
    /// sharding entirely; `Some(cfg)` tunes split and threshold.
    pub fn with_intra(
        workers: usize,
        dims: ScratchDims,
        n_models: usize,
        intra: Option<IntraCfg>,
    ) -> Self {
        Self::build(workers, dims, n_models, intra)
    }

    /// Pool sized for a registry: scratch pre-reserved for the max-dims
    /// union and one executed-images accounting slot per hosted model.
    pub fn for_registry(workers: usize, registry: &ModelRegistry) -> Self {
        Self::build(workers, registry.scratch_dims(), registry.len(), Some(IntraCfg::default()))
    }

    /// [`InferencePool::for_registry`] with explicit intra-image config
    /// (the `--intra-split` serving knob lands here).
    pub fn for_registry_intra(
        workers: usize,
        registry: &ModelRegistry,
        intra: Option<IntraCfg>,
    ) -> Self {
        Self::build(workers, registry.scratch_dims(), registry.len(), intra)
    }

    fn build(workers: usize, dims: ScratchDims, n_models: usize, intra: Option<IntraCfg>) -> Self {
        let workers = workers.max(1);
        let executed: Arc<Vec<AtomicU64>> =
            Arc::new((0..n_models.max(1)).map(|_| AtomicU64::new(0)).collect());
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        // Intra sharding needs at least 2 chunks AND a second worker to
        // steal them — on a 1-worker pool the submitter would shoulder
        // every chunk anyway and only pay the bookkeeping.
        let ctx = intra.and_then(|cfg| {
            let split = if cfg.split == 0 { workers } else { cfg.split };
            (workers > 1 && split > 1).then(|| IntraCtx {
                tx: tx.clone(),
                split,
                min_elems: cfg.min_elems,
            })
        });
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = rx.clone();
            let executed = executed.clone();
            let ctx = ctx.clone();
            handles.push(std::thread::spawn(move || worker_loop(&rx, dims, &executed, ctx)));
        }
        InferencePool {
            workers,
            tx: Some(tx),
            handles,
            executed,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Images successfully executed for `model_id` (0 when the id is
    /// outside the accounting range).
    pub fn executed_images(&self, model_id: u16) -> u64 {
        self.executed
            .get(model_id as usize)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Submit `n` images stored flat in `images` (n · img_elems f32s)
    /// for classification with `engine`, **without blocking**: the
    /// batch is sharded across workers immediately and `done` is called
    /// exactly once — with per-image argmax classes bit-identical to
    /// the sequential [`Engine::classify_batch`], or the first shard
    /// error — from the worker finishing the last shard. On error
    /// return (empty/ragged batch, pool shut down) `done` has NOT been
    /// called; the caller still owns the requests behind it.
    pub fn submit(
        &self,
        model_id: u16,
        engine: &Arc<Engine>,
        images: Arc<Vec<f32>>,
        n: usize,
        done: BatchDone,
    ) -> Result<()> {
        ensure!(n > 0, "empty batch submitted to pool");
        let img_elems = engine.img_elems();
        ensure!(
            images.len() == n * img_elems,
            "flat batch has {} f32s, want {} ({n} x {img_elems})",
            images.len(),
            n * img_elems
        );
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("inference pool shut down"))?;
        let shards = self.workers.min(n);
        let chunk = (n + shards - 1) / shards;
        let n_shards = (n + chunk - 1) / chunk;
        let batch = Arc::new(BatchState {
            preds: Mutex::new(vec![0usize; n]),
            err: Mutex::new(None),
            remaining: AtomicUsize::new(n_shards),
            done: Mutex::new(Some(done)),
        });
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            tx.send(Job::Shard(Shard {
                engine: engine.clone(),
                model_id,
                images: images.clone(),
                img_elems,
                start,
                end,
                batch: batch.clone(),
            }))
            .map_err(|_| anyhow!("inference pool workers gone"))?;
            start = end;
        }
        Ok(())
    }

    /// Classify `n` images and block for the result: [`InferencePool::submit`]
    /// plus a wait. Safe to call from many threads at once; each call
    /// has its own reply channel. Accounting lands in model slot 0.
    pub fn classify_flat(
        &self,
        engine: &Arc<Engine>,
        images: Arc<Vec<f32>>,
        n: usize,
    ) -> Result<Vec<usize>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        let (tx, rx) = channel();
        self.submit(
            0,
            engine,
            images,
            n,
            Box::new(move |r| {
                let _ = tx.send(r);
            }),
        )?;
        rx.recv()
            .map_err(|_| anyhow!("inference workers died mid-batch"))?
            .map_err(|e| anyhow!("inference worker: {e}"))
    }

    /// Convenience: classify a slice-of-slices batch (flattens once).
    pub fn classify_batch(&self, engine: &Arc<Engine>, images: &[&[f32]]) -> Result<Vec<usize>> {
        let mut flat = Vec::with_capacity(images.iter().map(|i| i.len()).sum());
        for img in images {
            flat.extend_from_slice(img);
        }
        self.classify_flat(engine, Arc::new(flat), images.len())
    }
}

impl Drop for InferencePool {
    fn drop(&mut self) {
        // Workers hold IntraCtx sender clones, so dropping our Sender
        // alone would never disconnect the channel — instead send one
        // Exit sentinel per worker. The channel is FIFO, so queued
        // shards drain (and their `done` callbacks run) before each
        // worker meets its Exit and returns.
        if let Some(tx) = self.tx.take() {
            for _ in 0..self.workers {
                let _ = tx.send(Job::Exit);
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<Job>>,
    dims: ScratchDims,
    executed: &[AtomicU64],
    intra: Option<IntraCtx>,
) {
    let mut scratch = EngineScratch::with_dims(dims);
    scratch.intra = intra;
    loop {
        // Hold the lock only for the blocking recv, not while running
        // inference, so idle workers can pick up the next shard.
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // another worker panicked holding the lock
        };
        let shard = match job {
            Err(_) => return, // every sender (incl. worker clones) gone
            Ok(Job::Exit) => return,
            Ok(Job::Intra(task)) => {
                // Helper path: steal chunks of another worker's image.
                // A panicking chunk poisons the task (the submitter
                // fails the image); the helper itself stays alive.
                let quant = &mut scratch.quant;
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    task.execute(quant);
                }));
                continue;
            }
            Ok(Job::Shard(shard)) => shard,
        };
        // Contain any engine panic: a dead worker would permanently
        // shrink the pool, so a panicking image becomes a shard error
        // instead. The scratch carries no invariants across calls
        // (every read region is fully overwritten first) — not even the
        // model identity, so reuse after an unwind or across models is
        // safe.
        let preds = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut preds = Vec::with_capacity(shard.end - shard.start);
            for i in shard.start..shard.end {
                let img = &shard.images[i * shard.img_elems..(i + 1) * shard.img_elems];
                match shard.engine.classify_scratch(img, &mut scratch) {
                    Ok(p) => preds.push(p),
                    Err(e) => return Err(format!("image {i}: {e:#}")),
                }
            }
            Ok(preds)
        }))
        .unwrap_or_else(|_| Err("engine panicked on this shard".to_string()));
        if preds.is_ok() {
            if let Some(c) = executed.get(shard.model_id as usize) {
                c.fetch_add((shard.end - shard.start) as u64, Ordering::Relaxed);
            }
        }
        // catch_unwind around the completion too: a panicking `done`
        // callback must not kill the worker (the batch submitter sees a
        // disconnected channel instead).
        let start = shard.start;
        let batch = shard.batch.clone();
        drop(shard);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            batch.complete(start, preds);
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::synth;
    use crate::util::rng::Rng;

    fn setup(seed: u64, n: usize) -> (Arc<Engine>, Vec<f32>, usize) {
        let mut rng = Rng::new(seed);
        let (topo, weights) = synth::tiny_model(&mut rng);
        let engine = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ));
        let elems = engine.img_elems();
        let images: Vec<f32> = (0..n * elems).map(|_| rng.normal()).collect();
        (engine, images, elems)
    }

    #[test]
    fn pool_matches_sequential_basic() {
        let (engine, images, elems) = setup(11, 10);
        let refs: Vec<&[f32]> = images.chunks_exact(elems).collect();
        let want = engine.classify_batch(&refs).unwrap();
        for workers in [1, 3, 16] {
            let pool = InferencePool::new(workers);
            assert_eq!(
                pool.classify_batch(&engine, &refs).unwrap(),
                want,
                "workers={workers}"
            );
        }
    }

    #[test]
    fn pool_reuse_across_batches_and_empty() {
        let (engine, images, elems) = setup(12, 6);
        let pool = InferencePool::new(2);
        assert!(pool.classify_batch(&engine, &[]).unwrap().is_empty());
        for split in [1usize, 2, 6] {
            let refs: Vec<&[f32]> = images.chunks_exact(elems).take(split).collect();
            let want = engine.classify_batch(&refs).unwrap();
            assert_eq!(pool.classify_batch(&engine, &refs).unwrap(), want);
        }
    }

    #[test]
    fn one_pool_serves_models_of_different_dims() {
        // tiny (3x8x8 in) and bench (3x16x16 in) interleaved through the
        // SAME pool: per-worker scratch must reshape between models
        // without leaking state in either direction.
        let (tiny, tiny_imgs, te) = setup(13, 4);
        let mut rng = Rng::new(14);
        let (topo, weights) = synth::bench_model(&mut rng);
        let bench = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ));
        let be = bench.img_elems();
        assert_ne!(te, be, "test needs heterogeneous dims");
        let bench_imgs: Vec<f32> = (0..4 * be).map(|_| rng.normal()).collect();

        let tiny_refs: Vec<&[f32]> = tiny_imgs.chunks_exact(te).collect();
        let bench_refs: Vec<&[f32]> = bench_imgs.chunks_exact(be).collect();
        let want_tiny = tiny.classify_batch(&tiny_refs).unwrap();
        let want_bench = bench.classify_batch(&bench_refs).unwrap();

        let dims = tiny.scratch_dims().union(bench.scratch_dims());
        let pool = InferencePool::with_scratch_dims(2, dims);
        for _ in 0..3 {
            assert_eq!(pool.classify_batch(&tiny, &tiny_refs).unwrap(), want_tiny);
            assert_eq!(pool.classify_batch(&bench, &bench_refs).unwrap(), want_bench);
        }
    }

    #[test]
    fn classify_flat_rejects_ragged_buffer() {
        let (engine, images, _) = setup(13, 2);
        let pool = InferencePool::new(2);
        let mut bad = images.clone();
        bad.pop();
        assert!(pool.classify_flat(&engine, Arc::new(bad), 2).is_err());
    }

    #[test]
    fn async_submit_completes_and_accounts_per_model() {
        use std::sync::mpsc::channel;
        let (tiny, tiny_imgs, te) = setup(21, 6);
        let mut rng = Rng::new(22);
        let (topo, weights) = synth::bench_model(&mut rng);
        let bench = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ));
        let be = bench.img_elems();
        let bench_imgs: Vec<f32> = (0..2 * be).map(|_| rng.normal()).collect();

        let registry = ModelRegistry::new(vec![
            ("tiny".into(), tiny.clone()),
            ("bench".into(), bench.clone()),
        ])
        .unwrap();
        let pool = InferencePool::for_registry(3, &registry);

        // several overlapping async submissions, mixed models
        let (tx, rx) = channel();
        for rep in 0..2 {
            let t = tx.clone();
            pool.submit(
                0,
                &tiny,
                Arc::new(tiny_imgs.clone()),
                6,
                Box::new(move |r| t.send((0u16, rep, r)).unwrap()),
            )
            .unwrap();
            let t = tx.clone();
            pool.submit(
                1,
                &bench,
                Arc::new(bench_imgs.clone()),
                2,
                Box::new(move |r| t.send((1u16, rep, r)).unwrap()),
            )
            .unwrap();
        }
        drop(tx);
        let tiny_refs: Vec<&[f32]> = tiny_imgs.chunks_exact(te).collect();
        let bench_refs: Vec<&[f32]> = bench_imgs.chunks_exact(be).collect();
        let want = [
            tiny.classify_batch(&tiny_refs).unwrap(),
            bench.classify_batch(&bench_refs).unwrap(),
        ];
        let mut seen = 0;
        while let Ok((id, _rep, r)) = rx.recv() {
            assert_eq!(r.unwrap(), want[id as usize], "model {id}");
            seen += 1;
        }
        assert_eq!(seen, 4);
        assert_eq!(pool.executed_images(0), 12);
        assert_eq!(pool.executed_images(1), 4);
        assert_eq!(pool.executed_images(7), 0, "out-of-range id reads 0");
    }

    #[test]
    fn submit_rejects_empty_and_ragged_without_consuming_done() {
        let (engine, images, _) = setup(23, 2);
        let pool = InferencePool::new(1);
        let called = Arc::new(AtomicUsize::new(0));
        let mk = |c: &Arc<AtomicUsize>| {
            let c = c.clone();
            Box::new(move |_r: Result<Vec<usize>, String>| {
                c.fetch_add(1, Ordering::SeqCst);
            }) as BatchDone
        };
        assert!(pool
            .submit(0, &engine, Arc::new(Vec::new()), 0, mk(&called))
            .is_err());
        let mut bad = images;
        bad.pop();
        assert!(pool.submit(0, &engine, Arc::new(bad), 2, mk(&called)).is_err());
        assert_eq!(called.load(Ordering::SeqCst), 0);
    }
}
