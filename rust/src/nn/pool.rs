//! Fixed worker thread-pool for batched inference.
//!
//! A batch of images is sharded into contiguous index ranges, one per
//! worker. Each worker is a long-lived thread owning one
//! [`EngineScratch`], so after warm-up the per-image hot loop performs
//! no allocation (the im2col patch buffer, border scratch, and
//! activation ping-pong buffers are all reused).
//!
//! Determinism: every image's forward pass is independent and the
//! per-image code path is exactly [`Engine::classify_scratch`] — the
//! same path the sequential [`Engine::classify_batch`] uses — so pooled
//! results are bit-identical to sequential results for any worker count
//! and any shard split. The pool property tests pin this down.
//!
//! Built on `std` only (rayon/crossbeam are unavailable offline): jobs
//! flow through an `mpsc` channel shared by workers behind a mutex, and
//! each job carries its own reply sender.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Result};

use super::engine::{Engine, EngineScratch};

/// One contiguous shard of a batch, dispatched to a single worker.
struct Shard {
    /// The whole batch, flattened (n · img_elems f32s), shared by ref-count.
    images: Arc<Vec<f32>>,
    img_elems: usize,
    /// Image index range [start, end) this worker classifies.
    start: usize,
    end: usize,
    reply: Sender<ShardReply>,
}

struct ShardReply {
    start: usize,
    /// Predicted classes for the shard, or the first error hit.
    preds: Result<Vec<usize>, String>,
}

/// Fixed-size inference thread-pool over a shared [`Engine`].
pub struct InferencePool {
    engine: Arc<Engine>,
    workers: usize,
    /// Job channel; `None` once shutdown has begun (Drop).
    tx: Option<Sender<Shard>>,
    handles: Vec<JoinHandle<()>>,
}

impl InferencePool {
    /// Spawn `workers` (min 1) threads, each with its own scratch.
    pub fn new(engine: Arc<Engine>, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Shard>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = rx.clone();
            let eng = engine.clone();
            handles.push(std::thread::spawn(move || worker_loop(&eng, &rx)));
        }
        InferencePool {
            engine,
            workers,
            tx: Some(tx),
            handles,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Classify `n` images stored flat in `images` (n · img_elems f32s).
    /// Returns per-image argmax classes, bit-identical to the sequential
    /// [`Engine::classify_batch`].
    pub fn classify_flat(&self, images: Arc<Vec<f32>>, n: usize) -> Result<Vec<usize>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        let img_elems = self.engine.img_elems();
        ensure!(
            images.len() == n * img_elems,
            "flat batch has {} f32s, want {} ({n} x {img_elems})",
            images.len(),
            n * img_elems
        );
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("inference pool shut down"))?;
        let shards = self.workers.min(n);
        let chunk = (n + shards - 1) / shards;
        let (rtx, rrx) = channel::<ShardReply>();
        let mut sent = 0usize;
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            tx.send(Shard {
                images: images.clone(),
                img_elems,
                start,
                end,
                reply: rtx.clone(),
            })
            .map_err(|_| anyhow!("inference pool workers gone"))?;
            sent += 1;
            start = end;
        }
        drop(rtx);
        let mut out = vec![0usize; n];
        for _ in 0..sent {
            let r = rrx
                .recv()
                .map_err(|_| anyhow!("inference worker died mid-batch"))?;
            let preds = r.preds.map_err(|e| anyhow!("inference worker: {e}"))?;
            out[r.start..r.start + preds.len()].copy_from_slice(&preds);
        }
        Ok(out)
    }

    /// Convenience: classify a slice-of-slices batch (flattens once).
    pub fn classify_batch(&self, images: &[&[f32]]) -> Result<Vec<usize>> {
        let mut flat = Vec::with_capacity(images.iter().map(|i| i.len()).sum());
        for img in images {
            flat.extend_from_slice(img);
        }
        self.classify_flat(Arc::new(flat), images.len())
    }
}

impl Drop for InferencePool {
    fn drop(&mut self) {
        // Closing the channel unblocks every worker's recv with Err.
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(engine: &Engine, rx: &Mutex<Receiver<Shard>>) {
    let mut scratch = EngineScratch::new();
    loop {
        // Hold the lock only for the blocking recv, not while running
        // inference, so idle workers can pick up the next shard.
        let shard = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // another worker panicked holding the lock
        };
        let Ok(shard) = shard else { return }; // pool dropped
        // Contain any engine panic: a dead worker would permanently
        // shrink the pool, so a panicking image becomes a shard error
        // instead. The scratch carries no invariants across calls
        // (every buffer is fully overwritten), so reuse after an
        // unwind is safe.
        let preds = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut preds = Vec::with_capacity(shard.end - shard.start);
            for i in shard.start..shard.end {
                let img = &shard.images[i * shard.img_elems..(i + 1) * shard.img_elems];
                match engine.classify_scratch(img, &mut scratch) {
                    Ok(p) => preds.push(p),
                    Err(e) => return Err(format!("image {i}: {e:#}")),
                }
            }
            Ok(preds)
        }))
        .unwrap_or_else(|_| Err("engine panicked on this shard".to_string()));
        // The batch submitter may have bailed already; ignore send errors.
        let _ = shard.reply.send(ShardReply {
            start: shard.start,
            preds,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::synth;
    use crate::util::rng::Rng;

    fn setup(seed: u64, n: usize) -> (Arc<Engine>, Vec<f32>, usize) {
        let mut rng = Rng::new(seed);
        let (topo, weights) = synth::tiny_model(&mut rng);
        let engine = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ));
        let elems = engine.img_elems();
        let images: Vec<f32> = (0..n * elems).map(|_| rng.normal()).collect();
        (engine, images, elems)
    }

    #[test]
    fn pool_matches_sequential_basic() {
        let (engine, images, elems) = setup(11, 10);
        let refs: Vec<&[f32]> = images.chunks_exact(elems).collect();
        let want = engine.classify_batch(&refs).unwrap();
        for workers in [1, 3, 16] {
            let pool = InferencePool::new(engine.clone(), workers);
            assert_eq!(pool.classify_batch(&refs).unwrap(), want, "workers={workers}");
        }
    }

    #[test]
    fn pool_reuse_across_batches_and_empty() {
        let (engine, images, elems) = setup(12, 6);
        let pool = InferencePool::new(engine.clone(), 2);
        assert!(pool.classify_batch(&[]).unwrap().is_empty());
        for split in [1usize, 2, 6] {
            let refs: Vec<&[f32]> = images.chunks_exact(elems).take(split).collect();
            let want = engine.classify_batch(&refs).unwrap();
            assert_eq!(pool.classify_batch(&refs).unwrap(), want);
        }
    }

    #[test]
    fn classify_flat_rejects_ragged_buffer() {
        let (engine, images, _) = setup(13, 2);
        let pool = InferencePool::new(engine, 2);
        let mut bad = images.clone();
        bad.pop();
        assert!(pool.classify_flat(Arc::new(bad), 2).is_err());
    }
}
