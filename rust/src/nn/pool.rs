//! Fixed worker thread-pool for batched inference.
//!
//! The pool is **model-agnostic**: each job (shard) carries the
//! `Arc<Engine>` it runs against, so one pool serves every model in a
//! [`crate::nn::registry::ModelRegistry`] without duplicating worker
//! threads. A batch of images is sharded into contiguous index ranges,
//! one per worker. Each worker is a long-lived thread owning one
//! [`EngineScratch`]; the scratch is model-agnostic too (grow-only
//! buffers, pre-sized to the max dims passed at construction), so after
//! warm-up the per-image hot loop performs no allocation even when
//! consecutive shards come from models of different shapes.
//!
//! Determinism: every image's forward pass is independent and the
//! per-image code path is exactly [`Engine::classify_scratch`] — the
//! same path the sequential [`Engine::classify_batch`] uses — so pooled
//! results are bit-identical to sequential results for any worker count,
//! any shard split, and any interleaving of models. The pool property
//! tests pin this down.
//!
//! Built on `std` only (rayon/crossbeam are unavailable offline): jobs
//! flow through an `mpsc` channel shared by workers behind a mutex, and
//! each job carries its own reply sender.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Result};

use super::engine::{Engine, EngineScratch, ScratchDims};

/// One contiguous shard of a batch, dispatched to a single worker.
struct Shard {
    /// The engine this shard runs against (jobs carry their model; the
    /// pool owns none).
    engine: Arc<Engine>,
    /// The whole batch, flattened (n · img_elems f32s), shared by ref-count.
    images: Arc<Vec<f32>>,
    img_elems: usize,
    /// Image index range [start, end) this worker classifies.
    start: usize,
    end: usize,
    reply: Sender<ShardReply>,
}

struct ShardReply {
    start: usize,
    /// Predicted classes for the shard, or the first error hit.
    preds: Result<Vec<usize>, String>,
}

/// Fixed-size, model-agnostic inference thread-pool.
pub struct InferencePool {
    workers: usize,
    /// Job channel; `None` once shutdown has begun (Drop).
    tx: Option<Sender<Shard>>,
    handles: Vec<JoinHandle<()>>,
}

impl InferencePool {
    /// Spawn `workers` (min 1) threads, each with its own scratch.
    pub fn new(workers: usize) -> Self {
        Self::with_scratch_dims(workers, ScratchDims::default())
    }

    /// Spawn workers whose scratch is pre-reserved for `dims` (use the
    /// registry's max-dims union so the largest model's first image
    /// doesn't pay reallocation).
    pub fn with_scratch_dims(workers: usize, dims: ScratchDims) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Shard>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = rx.clone();
            handles.push(std::thread::spawn(move || worker_loop(&rx, dims)));
        }
        InferencePool {
            workers,
            tx: Some(tx),
            handles,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Classify `n` images stored flat in `images` (n · img_elems f32s)
    /// with `engine`. Returns per-image argmax classes, bit-identical to
    /// the sequential [`Engine::classify_batch`]. Safe to call from many
    /// threads at once (per-model batchers share one pool); each call
    /// has its own reply channel.
    pub fn classify_flat(
        &self,
        engine: &Arc<Engine>,
        images: Arc<Vec<f32>>,
        n: usize,
    ) -> Result<Vec<usize>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        let img_elems = engine.img_elems();
        ensure!(
            images.len() == n * img_elems,
            "flat batch has {} f32s, want {} ({n} x {img_elems})",
            images.len(),
            n * img_elems
        );
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("inference pool shut down"))?;
        let shards = self.workers.min(n);
        let chunk = (n + shards - 1) / shards;
        let (rtx, rrx) = channel::<ShardReply>();
        let mut sent = 0usize;
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            tx.send(Shard {
                engine: engine.clone(),
                images: images.clone(),
                img_elems,
                start,
                end,
                reply: rtx.clone(),
            })
            .map_err(|_| anyhow!("inference pool workers gone"))?;
            sent += 1;
            start = end;
        }
        drop(rtx);
        let mut out = vec![0usize; n];
        for _ in 0..sent {
            let r = rrx
                .recv()
                .map_err(|_| anyhow!("inference worker died mid-batch"))?;
            let preds = r.preds.map_err(|e| anyhow!("inference worker: {e}"))?;
            out[r.start..r.start + preds.len()].copy_from_slice(&preds);
        }
        Ok(out)
    }

    /// Convenience: classify a slice-of-slices batch (flattens once).
    pub fn classify_batch(&self, engine: &Arc<Engine>, images: &[&[f32]]) -> Result<Vec<usize>> {
        let mut flat = Vec::with_capacity(images.iter().map(|i| i.len()).sum());
        for img in images {
            flat.extend_from_slice(img);
        }
        self.classify_flat(engine, Arc::new(flat), images.len())
    }
}

impl Drop for InferencePool {
    fn drop(&mut self) {
        // Closing the channel unblocks every worker's recv with Err.
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<Shard>>, dims: ScratchDims) {
    let mut scratch = EngineScratch::with_dims(dims);
    loop {
        // Hold the lock only for the blocking recv, not while running
        // inference, so idle workers can pick up the next shard.
        let shard = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // another worker panicked holding the lock
        };
        let Ok(shard) = shard else { return }; // pool dropped
        // Contain any engine panic: a dead worker would permanently
        // shrink the pool, so a panicking image becomes a shard error
        // instead. The scratch carries no invariants across calls
        // (every read region is fully overwritten first) — not even the
        // model identity, so reuse after an unwind or across models is
        // safe.
        let preds = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut preds = Vec::with_capacity(shard.end - shard.start);
            for i in shard.start..shard.end {
                let img = &shard.images[i * shard.img_elems..(i + 1) * shard.img_elems];
                match shard.engine.classify_scratch(img, &mut scratch) {
                    Ok(p) => preds.push(p),
                    Err(e) => return Err(format!("image {i}: {e:#}")),
                }
            }
            Ok(preds)
        }))
        .unwrap_or_else(|_| Err("engine panicked on this shard".to_string()));
        // The batch submitter may have bailed already; ignore send errors.
        let _ = shard.reply.send(ShardReply {
            start: shard.start,
            preds,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::synth;
    use crate::util::rng::Rng;

    fn setup(seed: u64, n: usize) -> (Arc<Engine>, Vec<f32>, usize) {
        let mut rng = Rng::new(seed);
        let (topo, weights) = synth::tiny_model(&mut rng);
        let engine = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ));
        let elems = engine.img_elems();
        let images: Vec<f32> = (0..n * elems).map(|_| rng.normal()).collect();
        (engine, images, elems)
    }

    #[test]
    fn pool_matches_sequential_basic() {
        let (engine, images, elems) = setup(11, 10);
        let refs: Vec<&[f32]> = images.chunks_exact(elems).collect();
        let want = engine.classify_batch(&refs).unwrap();
        for workers in [1, 3, 16] {
            let pool = InferencePool::new(workers);
            assert_eq!(
                pool.classify_batch(&engine, &refs).unwrap(),
                want,
                "workers={workers}"
            );
        }
    }

    #[test]
    fn pool_reuse_across_batches_and_empty() {
        let (engine, images, elems) = setup(12, 6);
        let pool = InferencePool::new(2);
        assert!(pool.classify_batch(&engine, &[]).unwrap().is_empty());
        for split in [1usize, 2, 6] {
            let refs: Vec<&[f32]> = images.chunks_exact(elems).take(split).collect();
            let want = engine.classify_batch(&refs).unwrap();
            assert_eq!(pool.classify_batch(&engine, &refs).unwrap(), want);
        }
    }

    #[test]
    fn one_pool_serves_models_of_different_dims() {
        // tiny (3x8x8 in) and bench (3x16x16 in) interleaved through the
        // SAME pool: per-worker scratch must reshape between models
        // without leaking state in either direction.
        let (tiny, tiny_imgs, te) = setup(13, 4);
        let mut rng = Rng::new(14);
        let (topo, weights) = synth::bench_model(&mut rng);
        let bench = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ));
        let be = bench.img_elems();
        assert_ne!(te, be, "test needs heterogeneous dims");
        let bench_imgs: Vec<f32> = (0..4 * be).map(|_| rng.normal()).collect();

        let tiny_refs: Vec<&[f32]> = tiny_imgs.chunks_exact(te).collect();
        let bench_refs: Vec<&[f32]> = bench_imgs.chunks_exact(be).collect();
        let want_tiny = tiny.classify_batch(&tiny_refs).unwrap();
        let want_bench = bench.classify_batch(&bench_refs).unwrap();

        let dims = tiny.scratch_dims().union(bench.scratch_dims());
        let pool = InferencePool::with_scratch_dims(2, dims);
        for _ in 0..3 {
            assert_eq!(pool.classify_batch(&tiny, &tiny_refs).unwrap(), want_tiny);
            assert_eq!(pool.classify_batch(&bench, &bench_refs).unwrap(), want_bench);
        }
    }

    #[test]
    fn classify_flat_rejects_ragged_buffer() {
        let (engine, images, _) = setup(13, 2);
        let pool = InferencePool::new(2);
        let mut bad = images.clone();
        bad.pop();
        assert!(pool.classify_flat(&engine, Arc::new(bad), 2).is_err());
    }
}
