//! Runtime-dispatched SIMD microkernels for the serving hot loops.
//!
//! Four loop families live here: the border quantize-dequantize column
//! pass (`quant/border.rs`), the im2col interior-row gather, the
//! grouped-GEMM dot product, and the cache-blocked register-tiled GEMM
//! microkernel (`gemm_tile_on`, driven by the packed-panel machinery in
//! `nn/im2col.rs`). Each has an AVX2 path (x86_64), a NEON path
//! (aarch64), and a scalar reference that is always compiled;
//! `active()` picks the best available backend at first use (override
//! with `AQUANT_KERNELS=scalar|avx2|neon|auto`).
//!
//! **Bit-identity contract.** Every backend produces bit-identical f32
//! results for the same inputs — serving bit-identity is the invariant
//! every prior PR preserved, and the differential property suite
//! (`rust/tests/kernel_props.rs`) pins it. Three rules make that hold:
//!
//! 1. min/max use *compare-select* semantics — `sel_max(a,b) = if a > b
//!    {a} else {b}` — exactly what `_mm256_max_ps`/`_mm256_min_ps`
//!    compute, and what NEON reproduces via `vbslq_f32(vcgtq_f32(a,b),
//!    a, b)` (NOT `vmaxq_f32`, whose NaN/±0 handling differs). For
//!    non-NaN inputs this matches the old `f32::clamp`; a NaN input now
//!    clamps to the lower bound instead of propagating, which is
//!    acceptable for this pipeline (NaN activations were already
//!    undefined behavior upstream).
//! 2. no FMA anywhere — separate mul/add keep the double rounding the
//!    scalar code performs, so every element-wise op (mul, add, div,
//!    ceil) is IEEE correctly rounded and therefore identical per lane
//!    across backends.
//! 3. reductions (`dot` and the tiled GEMM) use a lane-blocked
//!    accumulator with a fixed halving fold that matches the SIMD
//!    horizontal-reduce tree: LANES partial sums, fold by halves to 2,
//!    final `acc[0] + acc[1]`, sequential tail. The scalar fallback
//!    uses the same tree, so a scalar machine and an AVX2 machine of
//!    the same LANES width agree bitwise with each other and with the
//!    vector path. The tiled GEMM vectorizes along K with one
//!    LANES-wide accumulator per output element, carried across KC
//!    strips (KC is a LANES multiple, so strip boundaries never split a
//!    lane block) — which makes its reduction order *identical* to
//!    `dot`'s for every tile shape.
//!
//! **Opt-in fast mode.** `AQUANT_FAST=fma` (or `--fast-kernels`)
//! switches the tiled GEMM to FMA accumulation with relaxed reduction
//! order. That mode is explicitly OUTSIDE the bit-identity contract:
//! results may differ in low-order bits across backends and tile
//! shapes (pinned allclose-not-bitwise by `kernel_props.rs`). Default
//! is exact; the resolved mode is surfaced in `/stats`.

use std::sync::OnceLock;

/// Accumulator block width for `dot` (8 f32 = one AVX2 register on
/// x86_64, 4 = one NEON register elsewhere). The scalar fallback uses
/// the same width so its reduction tree matches the vector path.
pub const LANES: usize = if cfg!(target_arch = "x86_64") { 8 } else { 4 };

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// Whether this backend can run on the current CPU.
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Best backend the current CPU supports.
    pub fn best() -> Backend {
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
        #[cfg(target_arch = "aarch64")]
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Backend::Neon;
        }
        Backend::Scalar
    }

    /// All variants, for differential tests to iterate (filter with
    /// `available()`).
    pub fn all() -> [Backend; 3] {
        [Backend::Scalar, Backend::Avx2, Backend::Neon]
    }
}

static ACTIVE: OnceLock<Backend> = OnceLock::new();

/// The process-wide backend, resolved once: `AQUANT_KERNELS` env if set
/// and available (with a stderr warning on fallback), else `best()`.
pub fn active() -> Backend {
    *ACTIVE.get_or_init(|| {
        let req = std::env::var("AQUANT_KERNELS").unwrap_or_default();
        let pick = match req.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => None,
            "scalar" => Some(Backend::Scalar),
            "avx2" => Some(Backend::Avx2),
            "neon" => Some(Backend::Neon),
            other => {
                eprintln!("aquant: unknown AQUANT_KERNELS={other:?}; using auto");
                None
            }
        };
        match pick {
            Some(b) if b.available() => b,
            Some(b) => {
                let best = Backend::best();
                eprintln!(
                    "aquant: AQUANT_KERNELS={} unavailable on this CPU; using {}",
                    b.name(),
                    best.name()
                );
                best
            }
            None => Backend::best(),
        }
    })
}

// ---------------------------------------------------------------------------
// Tiled-GEMM geometry + the opt-in fast mode
// ---------------------------------------------------------------------------

/// Register-tile rows (im2col patches) per `gemm_tile_on` call.
pub const MR: usize = 4;
/// Register-tile columns (output channels) per B panel.
pub const NR: usize = 4;
/// K-strip length: B panels and the packed-A scratch are laid out in
/// KC-element strips so one `MR x NR` tile's working set (A strip rows +
/// B panel strip) stays L1-resident while accumulators live in
/// registers. KC must be a LANES multiple: strip boundaries then land
/// exactly on `dot`'s lane-block boundaries, which is what keeps the
/// tiled reduction order bit-identical to `scalar::dot` (only the final
/// strip may be ragged, and its tail is summed sequentially like dot's).
pub const KC: usize = 256;
const _: () = assert!(KC % LANES == 0);

/// GEMM accumulation mode. `Exact` (default) is inside the bit-identity
/// contract; `Fma` fuses multiply-add and relaxes reduction order for
/// throughput, and is only allclose to the exact result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FastMode {
    Exact,
    Fma,
}

impl FastMode {
    pub fn name(self) -> &'static str {
        match self {
            FastMode::Exact => "exact",
            FastMode::Fma => "fma",
        }
    }
}

static FAST: OnceLock<FastMode> = OnceLock::new();

/// Downgrade an FMA request the hardware can't honor. NEON and the
/// scalar `mul_add` path always can; AVX2 without the FMA extension
/// (pre-Haswell) cannot, so the request falls back to exact with a
/// warning rather than silently changing meaning per host.
fn resolve_fast(requested: bool) -> FastMode {
    if !requested {
        return FastMode::Exact;
    }
    #[cfg(target_arch = "x86_64")]
    if active() == Backend::Avx2 && !is_x86_feature_detected!("fma") {
        eprintln!("aquant: fast kernels requested but the CPU lacks FMA; staying exact");
        return FastMode::Exact;
    }
    FastMode::Fma
}

/// The process-wide GEMM mode, resolved once: `AQUANT_FAST` env
/// (`fma` opts in; empty/`exact`/`off` stay exact) unless
/// `request_fast_kernels()` already pinned it.
pub fn fast_mode() -> FastMode {
    *FAST.get_or_init(|| {
        let req = std::env::var("AQUANT_FAST").unwrap_or_default();
        let want = match req.trim().to_ascii_lowercase().as_str() {
            "" | "exact" | "off" => false,
            "fma" => true,
            other => {
                eprintln!("aquant: unknown AQUANT_FAST={other:?}; staying exact");
                false
            }
        };
        resolve_fast(want)
    })
}

/// CLI hook for `--fast-kernels`: request FMA before first kernel use.
/// Returns the mode that actually won (a prior env resolution or a
/// missing-FMA downgrade may keep it exact).
pub fn request_fast_kernels() -> FastMode {
    let _ = FAST.set(resolve_fast(true));
    fast_mode()
}

// ---------------------------------------------------------------------------
// Shared element-wise helpers (the scalar *definition* of every op; the
// vector paths are transcriptions of exactly these expression trees).
// ---------------------------------------------------------------------------

/// `_mm256_max_ps` semantics: second operand wins on NaN or equality.
#[inline(always)]
fn sel_max(a: f32, b: f32) -> f32 {
    if a > b {
        a
    } else {
        b
    }
}

/// `_mm256_min_ps` semantics: second operand wins on NaN or equality.
#[inline(always)]
fn sel_min(a: f32, b: f32) -> f32 {
    if a < b {
        a
    } else {
        b
    }
}

/// Fast `sigmoid(2.5u) − 0.5 = 0.5·tanh(1.25u)` (clamped 7th-order
/// Lambert rational; max abs error vs the exact offset < 2e-3). The op
/// order here is the bit-identity contract — every backend evaluates
/// this exact expression tree, term by term.
#[inline(always)]
pub fn fast_offset(u: f32) -> f32 {
    let x = sel_min(sel_max(1.25 * u, -4.0), 4.0);
    let x2 = x * x;
    let p = x * (10395.0 + x2 * (1260.0 + x2 * 21.0));
    let q = 10395.0 + x2 * (4725.0 + x2 * (210.0 + x2));
    0.5 * (p / q)
}

/// Quantize-dequantize one normalized activation against its border.
#[inline(always)]
fn quantize(xs: f32, border: f32, s: f32, qmin: f32, qmax: f32) -> f32 {
    s * sel_min(sel_max((xs - border).ceil(), qmin), qmax)
}

// ---------------------------------------------------------------------------
// Scalar reference backend
// ---------------------------------------------------------------------------

pub(crate) mod scalar {
    use super::*;

    pub fn nearest_col(col: &mut [f32], s: f32, inv_s: f32, qmin: f32, qmax: f32) {
        for v in col.iter_mut() {
            *v = quantize(*v * inv_s, 0.5, s, qmin, qmax);
        }
    }

    pub fn quant_col_lin(
        col: &mut [f32],
        b0: &[f32],
        b1: &[f32],
        s: f32,
        inv_s: f32,
        qmin: f32,
        qmax: f32,
    ) {
        for (r, v) in col.iter_mut().enumerate() {
            let xs = *v * inv_s;
            let u = b1[r] * xs + b0[r];
            *v = quantize(xs, 0.5 + fast_offset(u), s, qmin, qmax);
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn quant_col_quad(
        col: &mut [f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        s: f32,
        inv_s: f32,
        qmin: f32,
        qmax: f32,
    ) {
        for (r, v) in col.iter_mut().enumerate() {
            let xs = *v * inv_s;
            let u = (b2[r] * xs + b1[r]) * xs + b0[r];
            *v = quantize(xs, 0.5 + fast_offset(u), s, qmin, qmax);
        }
    }

    pub fn borders_col_lin(xs: &[f32], b0: &[f32], b1: &[f32], out: &mut [f32]) {
        for r in 0..xs.len() {
            let u = b1[r] * xs[r] + b0[r];
            out[r] = 0.5 + fast_offset(u);
        }
    }

    pub fn borders_col_quad(xs: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], out: &mut [f32]) {
        for r in 0..xs.len() {
            let u = (b2[r] * xs[r] + b1[r]) * xs[r] + b0[r];
            out[r] = 0.5 + fast_offset(u);
        }
    }

    pub fn scale_col(src: &[f32], inv_s: f32, dst: &mut [f32]) {
        for (d, v) in dst.iter_mut().zip(src) {
            *d = v * inv_s;
        }
    }

    pub fn round_col(col: &mut [f32], xs: &[f32], borders: &[f32], s: f32, qmin: f32, qmax: f32) {
        for r in 0..col.len() {
            col[r] = quantize(xs[r], borders[r], s, qmin, qmax);
        }
    }

    /// Lane-blocked dot product whose reduction tree matches the SIMD
    /// horizontal reduce bit for bit (see the module contract).
    pub fn dot(w: &[f32], x: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), x.len());
        let n = w.len();
        let mut acc = [0.0f32; LANES];
        let blocks = n / LANES * LANES;
        let mut i = 0;
        while i < blocks {
            for (j, a) in acc.iter_mut().enumerate() {
                *a += w[i + j] * x[i + j];
            }
            i += LANES;
        }
        let mut width = LANES / 2;
        while width > 1 {
            for j in 0..width {
                acc[j] += acc[j + width];
            }
            width /= 2;
        }
        let mut sum = acc[0] + acc[1];
        while i < n {
            sum += w[i] * x[i];
            i += 1;
        }
        sum
    }

    /// One `mr x nr` register tile of the packed GEMM (see the layout
    /// docs in `nn/im2col.rs`). `a` is a packed-A group block of `mc`
    /// rows in KC strips (strip `s` starts at `mc * s*KC`, row `mi` of a
    /// strip of length `ls` at `+ mi*ls`); `bp` is one B panel of `nr`
    /// channel rows in the same strip layout. Each output element keeps
    /// a LANES-wide accumulator carried across every strip, folded once
    /// at the end with `dot`'s halving tree, then the ragged tail of
    /// the final strip is added sequentially — the exact reduction
    /// order of `scalar::dot`, so the exact mode is bit-identical to
    /// the dot-per-row reference. `fma` switches accumulation to
    /// `mul_add` (outside the bit-identity contract).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_tile(
        a: &[f32],
        mc: usize,
        m0: usize,
        mr: usize,
        bp: &[f32],
        nr: usize,
        k: usize,
        fma: bool,
        sums: &mut [f32],
    ) {
        debug_assert!(mr <= MR && nr <= NR && sums.len() >= mr * nr);
        let mut acc = [[[0.0f32; LANES]; NR]; MR];
        // Tail bookkeeping for the final strip (vb..ls are the elements
        // past the last full lane block; summed after the fold).
        let (mut tab, mut tbb, mut tls, mut tvb) = (0usize, 0usize, 0usize, 0usize);
        let mut kbase = 0;
        while kbase < k {
            let ls = (k - kbase).min(KC);
            let abase = mc * kbase;
            let bbase = nr * kbase;
            let vb = ls / LANES * LANES;
            let mut t = 0;
            while t < vb {
                for (mi, am) in acc.iter_mut().enumerate().take(mr) {
                    for (ni, an) in am.iter_mut().enumerate().take(nr) {
                        for (j, aj) in an.iter_mut().enumerate() {
                            let p = a[abase + (m0 + mi) * ls + t + j];
                            let q = bp[bbase + ni * ls + t + j];
                            if fma {
                                *aj = p.mul_add(q, *aj);
                            } else {
                                *aj += p * q;
                            }
                        }
                    }
                }
                t += LANES;
            }
            (tab, tbb, tls, tvb) = (abase, bbase, ls, vb);
            kbase += ls;
        }
        for mi in 0..mr {
            for ni in 0..nr {
                let av = &mut acc[mi][ni];
                let mut width = LANES / 2;
                while width > 1 {
                    for j in 0..width {
                        av[j] += av[j + width];
                    }
                    width /= 2;
                }
                let mut sum = av[0] + av[1];
                for t in tvb..tls {
                    sum += a[tab + (m0 + mi) * tls + t] * bp[tbb + ni * tls + t];
                }
                sums[mi * nr + ni] = sum;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 backend (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    const W: usize = 8;

    /// `fast_offset` on 8 lanes: a literal transcription of the scalar
    /// expression tree (no FMA; mul/add/div are correctly rounded, so
    /// each lane matches the scalar result bitwise).
    // SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    unsafe fn fast_offset_v(u: __m256) -> __m256 {
        let x = _mm256_min_ps(
            _mm256_max_ps(_mm256_mul_ps(_mm256_set1_ps(1.25), u), _mm256_set1_ps(-4.0)),
            _mm256_set1_ps(4.0),
        );
        let x2 = _mm256_mul_ps(x, x);
        let t1 = _mm256_mul_ps(x2, _mm256_set1_ps(21.0));
        let t2 = _mm256_add_ps(_mm256_set1_ps(1260.0), t1);
        let t3 = _mm256_mul_ps(x2, t2);
        let t4 = _mm256_add_ps(_mm256_set1_ps(10395.0), t3);
        let p = _mm256_mul_ps(x, t4);
        let i1 = _mm256_add_ps(_mm256_set1_ps(210.0), x2);
        let i2 = _mm256_mul_ps(x2, i1);
        let i3 = _mm256_add_ps(_mm256_set1_ps(4725.0), i2);
        let i4 = _mm256_mul_ps(x2, i3);
        let q = _mm256_add_ps(_mm256_set1_ps(10395.0), i4);
        _mm256_mul_ps(_mm256_set1_ps(0.5), _mm256_div_ps(p, q))
    }

    // SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    unsafe fn quantize_v(xs: __m256, border: __m256, s: __m256, qmin: __m256, qmax: __m256) -> __m256 {
        let q = _mm256_ceil_ps(_mm256_sub_ps(xs, border));
        _mm256_mul_ps(s, _mm256_min_ps(_mm256_max_ps(q, qmin), qmax))
    }

    // SAFETY: caller must ensure AVX2 is available; pointer arithmetic
    // stays inside `col` (vector blocks then a scalar tail).
    #[target_feature(enable = "avx2")]
    pub unsafe fn nearest_col(col: &mut [f32], s: f32, inv_s: f32, qmin: f32, qmax: f32) {
        let (sv, iv) = (_mm256_set1_ps(s), _mm256_set1_ps(inv_s));
        let (lo, hi) = (_mm256_set1_ps(qmin), _mm256_set1_ps(qmax));
        let half = _mm256_set1_ps(0.5);
        let n = col.len();
        let blocks = n / W * W;
        let p = col.as_mut_ptr();
        let mut i = 0;
        while i < blocks {
            let xs = _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), iv);
            _mm256_storeu_ps(p.add(i), quantize_v(xs, half, sv, lo, hi));
            i += W;
        }
        scalar::nearest_col(&mut col[blocks..], s, inv_s, qmin, qmax);
    }

    // SAFETY: caller must ensure AVX2 is available and the border slices
    // are at least `col.len()` long (engine layouts guarantee it).
    #[target_feature(enable = "avx2")]
    pub unsafe fn quant_col_lin(
        col: &mut [f32],
        b0: &[f32],
        b1: &[f32],
        s: f32,
        inv_s: f32,
        qmin: f32,
        qmax: f32,
    ) {
        let (sv, iv) = (_mm256_set1_ps(s), _mm256_set1_ps(inv_s));
        let (lo, hi) = (_mm256_set1_ps(qmin), _mm256_set1_ps(qmax));
        let half = _mm256_set1_ps(0.5);
        let n = col.len();
        let blocks = n / W * W;
        let p = col.as_mut_ptr();
        let mut i = 0;
        while i < blocks {
            let xs = _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), iv);
            let u = _mm256_add_ps(
                _mm256_mul_ps(_mm256_loadu_ps(b1.as_ptr().add(i)), xs),
                _mm256_loadu_ps(b0.as_ptr().add(i)),
            );
            let border = _mm256_add_ps(half, fast_offset_v(u));
            _mm256_storeu_ps(p.add(i), quantize_v(xs, border, sv, lo, hi));
            i += W;
        }
        scalar::quant_col_lin(&mut col[blocks..], &b0[blocks..], &b1[blocks..], s, inv_s, qmin, qmax);
    }

    // SAFETY: caller must ensure AVX2 is available and the border slices
    // are at least `col.len()` long (engine layouts guarantee it).
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn quant_col_quad(
        col: &mut [f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        s: f32,
        inv_s: f32,
        qmin: f32,
        qmax: f32,
    ) {
        let (sv, iv) = (_mm256_set1_ps(s), _mm256_set1_ps(inv_s));
        let (lo, hi) = (_mm256_set1_ps(qmin), _mm256_set1_ps(qmax));
        let half = _mm256_set1_ps(0.5);
        let n = col.len();
        let blocks = n / W * W;
        let p = col.as_mut_ptr();
        let mut i = 0;
        while i < blocks {
            let xs = _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), iv);
            let t = _mm256_add_ps(
                _mm256_mul_ps(_mm256_loadu_ps(b2.as_ptr().add(i)), xs),
                _mm256_loadu_ps(b1.as_ptr().add(i)),
            );
            let u = _mm256_add_ps(_mm256_mul_ps(t, xs), _mm256_loadu_ps(b0.as_ptr().add(i)));
            let border = _mm256_add_ps(half, fast_offset_v(u));
            _mm256_storeu_ps(p.add(i), quantize_v(xs, border, sv, lo, hi));
            i += W;
        }
        scalar::quant_col_quad(
            &mut col[blocks..],
            &b0[blocks..],
            &b1[blocks..],
            &b2[blocks..],
            s,
            inv_s,
            qmin,
            qmax,
        );
    }

    // SAFETY: caller must ensure AVX2 is available and all slices are at
    // least `xs.len()` long.
    #[target_feature(enable = "avx2")]
    pub unsafe fn borders_col_lin(xs: &[f32], b0: &[f32], b1: &[f32], out: &mut [f32]) {
        let half = _mm256_set1_ps(0.5);
        let n = xs.len();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            let x = _mm256_loadu_ps(xs.as_ptr().add(i));
            let u = _mm256_add_ps(
                _mm256_mul_ps(_mm256_loadu_ps(b1.as_ptr().add(i)), x),
                _mm256_loadu_ps(b0.as_ptr().add(i)),
            );
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(half, fast_offset_v(u)));
            i += W;
        }
        scalar::borders_col_lin(&xs[blocks..], &b0[blocks..], &b1[blocks..], &mut out[blocks..]);
    }

    // SAFETY: caller must ensure AVX2 is available and all slices are at
    // least `xs.len()` long.
    #[target_feature(enable = "avx2")]
    pub unsafe fn borders_col_quad(xs: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], out: &mut [f32]) {
        let half = _mm256_set1_ps(0.5);
        let n = xs.len();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            let x = _mm256_loadu_ps(xs.as_ptr().add(i));
            let t = _mm256_add_ps(
                _mm256_mul_ps(_mm256_loadu_ps(b2.as_ptr().add(i)), x),
                _mm256_loadu_ps(b1.as_ptr().add(i)),
            );
            let u = _mm256_add_ps(_mm256_mul_ps(t, x), _mm256_loadu_ps(b0.as_ptr().add(i)));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(half, fast_offset_v(u)));
            i += W;
        }
        scalar::borders_col_quad(
            &xs[blocks..],
            &b0[blocks..],
            &b1[blocks..],
            &b2[blocks..],
            &mut out[blocks..],
        );
    }

    // SAFETY: caller must ensure AVX2 is available and `dst` is at least
    // `src.len()` long.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_col(src: &[f32], inv_s: f32, dst: &mut [f32]) {
        let iv = _mm256_set1_ps(inv_s);
        let n = src.len();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            _mm256_storeu_ps(
                dst.as_mut_ptr().add(i),
                _mm256_mul_ps(_mm256_loadu_ps(src.as_ptr().add(i)), iv),
            );
            i += W;
        }
        scalar::scale_col(&src[blocks..], inv_s, &mut dst[blocks..]);
    }

    // SAFETY: caller must ensure AVX2 is available and `xs`/`borders`
    // are at least `col.len()` long.
    #[target_feature(enable = "avx2")]
    pub unsafe fn round_col(
        col: &mut [f32],
        xs: &[f32],
        borders: &[f32],
        s: f32,
        qmin: f32,
        qmax: f32,
    ) {
        let sv = _mm256_set1_ps(s);
        let (lo, hi) = (_mm256_set1_ps(qmin), _mm256_set1_ps(qmax));
        let n = col.len();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            let x = _mm256_loadu_ps(xs.as_ptr().add(i));
            let b = _mm256_loadu_ps(borders.as_ptr().add(i));
            _mm256_storeu_ps(col.as_mut_ptr().add(i), quantize_v(x, b, sv, lo, hi));
            i += W;
        }
        scalar::round_col(&mut col[blocks..], &xs[blocks..], &borders[blocks..], s, qmin, qmax);
    }

    // SAFETY: caller must ensure AVX2 is available; `w`/`x` must be the
    // same length (debug-asserted).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(w: &[f32], x: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), x.len());
        let n = w.len();
        let mut acc = _mm256_setzero_ps();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            let wv = _mm256_loadu_ps(w.as_ptr().add(i));
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, xv));
            i += W;
        }
        // Horizontal reduce tree matched by the scalar fold: [0..4)+[4..8),
        // then pairs, then lanes 0+1.
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps::<1>(acc);
        let t = _mm_add_ps(lo, hi);
        let t2 = _mm_add_ps(t, _mm_movehl_ps(t, t));
        let t3 = _mm_add_ss(t2, _mm_shuffle_ps::<1>(t2, t2));
        let mut sum = _mm_cvtss_f32(t3);
        while i < n {
            sum += w[i] * x[i];
            i += 1;
        }
        sum
    }

    /// `dot`'s horizontal reduce tree on one register: [0..4)+[4..8),
    /// pairs, lanes 0+1 — matched by the scalar halving fold.
    // SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    unsafe fn hreduce(acc: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps::<1>(acc);
        let t = _mm_add_ps(lo, hi);
        let t2 = _mm_add_ps(t, _mm_movehl_ps(t, t));
        let t3 = _mm_add_ss(t2, _mm_shuffle_ps::<1>(t2, t2));
        _mm_cvtss_f32(t3)
    }

    /// Vector transcription of `scalar::gemm_tile` (exact mode): one
    /// W-wide accumulator per output element, carried across strips,
    /// folded with `dot`'s tree, sequential ragged tail — bit-identical
    /// to the scalar tile and to `dot` per element (W == LANES here).
    // SAFETY: caller must ensure AVX2 is available; slice indexing stays
    // bounds-checked.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn gemm_tile(
        a: &[f32],
        mc: usize,
        m0: usize,
        mr: usize,
        bp: &[f32],
        nr: usize,
        k: usize,
        sums: &mut [f32],
    ) {
        debug_assert!(mr <= MR && nr <= NR && sums.len() >= mr * nr);
        let mut acc = [[_mm256_setzero_ps(); NR]; MR];
        let (mut tab, mut tbb, mut tls, mut tvb) = (0usize, 0usize, 0usize, 0usize);
        let mut kbase = 0;
        while kbase < k {
            let ls = (k - kbase).min(KC);
            let abase = mc * kbase;
            let bbase = nr * kbase;
            let vb = ls / W * W;
            let mut t = 0;
            while t < vb {
                let mut av = [_mm256_setzero_ps(); MR];
                for (mi, v) in av.iter_mut().enumerate().take(mr) {
                    *v = _mm256_loadu_ps(a.as_ptr().add(abase + (m0 + mi) * ls + t));
                }
                for ni in 0..nr {
                    let bv = _mm256_loadu_ps(bp.as_ptr().add(bbase + ni * ls + t));
                    for (mi, v) in av.iter().enumerate().take(mr) {
                        acc[mi][ni] = _mm256_add_ps(acc[mi][ni], _mm256_mul_ps(*v, bv));
                    }
                }
                t += W;
            }
            (tab, tbb, tls, tvb) = (abase, bbase, ls, vb);
            kbase += ls;
        }
        for mi in 0..mr {
            for ni in 0..nr {
                let mut sum = hreduce(acc[mi][ni]);
                for t in tvb..tls {
                    sum += a[tab + (m0 + mi) * tls + t] * bp[tbb + ni * tls + t];
                }
                sums[mi * nr + ni] = sum;
            }
        }
    }

    /// FMA variant (opt-in `AQUANT_FAST=fma`): fused multiply-add, same
    /// loop structure but relaxed rounding — allclose, NOT bit-identical.
    // SAFETY: caller must ensure both AVX2 and FMA are available (the
    // dispatcher's match guard checks `is_x86_feature_detected!("fma")`).
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn gemm_tile_fma(
        a: &[f32],
        mc: usize,
        m0: usize,
        mr: usize,
        bp: &[f32],
        nr: usize,
        k: usize,
        sums: &mut [f32],
    ) {
        debug_assert!(mr <= MR && nr <= NR && sums.len() >= mr * nr);
        let mut acc = [[_mm256_setzero_ps(); NR]; MR];
        let (mut tab, mut tbb, mut tls, mut tvb) = (0usize, 0usize, 0usize, 0usize);
        let mut kbase = 0;
        while kbase < k {
            let ls = (k - kbase).min(KC);
            let abase = mc * kbase;
            let bbase = nr * kbase;
            let vb = ls / W * W;
            let mut t = 0;
            while t < vb {
                let mut av = [_mm256_setzero_ps(); MR];
                for (mi, v) in av.iter_mut().enumerate().take(mr) {
                    *v = _mm256_loadu_ps(a.as_ptr().add(abase + (m0 + mi) * ls + t));
                }
                for ni in 0..nr {
                    let bv = _mm256_loadu_ps(bp.as_ptr().add(bbase + ni * ls + t));
                    for (mi, v) in av.iter().enumerate().take(mr) {
                        acc[mi][ni] = _mm256_fmadd_ps(*v, bv, acc[mi][ni]);
                    }
                }
                t += W;
            }
            (tab, tbb, tls, tvb) = (abase, bbase, ls, vb);
            kbase += ls;
        }
        for mi in 0..mr {
            for ni in 0..nr {
                let mut sum = hreduce(acc[mi][ni]);
                for t in tvb..tls {
                    sum += a[tab + (m0 + mi) * tls + t] * bp[tbb + ni * tls + t];
                }
                sums[mi * nr + ni] = sum;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// NEON backend (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::*;
    use std::arch::aarch64::*;

    const W: usize = 4;

    /// `_mm256_max_ps` semantics on NEON: compare-then-select, NOT
    /// `vmaxq_f32` (FMAX's NaN/±0 handling differs from SSE/AVX max).
    // SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    unsafe fn sel_max_v(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vbslq_f32(vcgtq_f32(a, b), a, b)
    }

    // SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    unsafe fn sel_min_v(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vbslq_f32(vcltq_f32(a, b), a, b)
    }

    // SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    unsafe fn fast_offset_v(u: float32x4_t) -> float32x4_t {
        let x = sel_min_v(
            sel_max_v(vmulq_f32(vdupq_n_f32(1.25), u), vdupq_n_f32(-4.0)),
            vdupq_n_f32(4.0),
        );
        let x2 = vmulq_f32(x, x);
        let t1 = vmulq_f32(x2, vdupq_n_f32(21.0));
        let t2 = vaddq_f32(vdupq_n_f32(1260.0), t1);
        let t3 = vmulq_f32(x2, t2);
        let t4 = vaddq_f32(vdupq_n_f32(10395.0), t3);
        let p = vmulq_f32(x, t4);
        let i1 = vaddq_f32(vdupq_n_f32(210.0), x2);
        let i2 = vmulq_f32(x2, i1);
        let i3 = vaddq_f32(vdupq_n_f32(4725.0), i2);
        let i4 = vmulq_f32(x2, i3);
        let q = vaddq_f32(vdupq_n_f32(10395.0), i4);
        vmulq_f32(vdupq_n_f32(0.5), vdivq_f32(p, q))
    }

    // SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    unsafe fn quantize_v(
        xs: float32x4_t,
        border: float32x4_t,
        s: float32x4_t,
        qmin: float32x4_t,
        qmax: float32x4_t,
    ) -> float32x4_t {
        let q = vrndpq_f32(vsubq_f32(xs, border));
        vmulq_f32(s, sel_min_v(sel_max_v(q, qmin), qmax))
    }

    // SAFETY: caller must ensure NEON is available; pointer arithmetic
    // stays inside `col` (vector blocks then a scalar tail).
    #[target_feature(enable = "neon")]
    pub unsafe fn nearest_col(col: &mut [f32], s: f32, inv_s: f32, qmin: f32, qmax: f32) {
        let (sv, iv) = (vdupq_n_f32(s), vdupq_n_f32(inv_s));
        let (lo, hi) = (vdupq_n_f32(qmin), vdupq_n_f32(qmax));
        let half = vdupq_n_f32(0.5);
        let n = col.len();
        let blocks = n / W * W;
        let p = col.as_mut_ptr();
        let mut i = 0;
        while i < blocks {
            let xs = vmulq_f32(vld1q_f32(p.add(i)), iv);
            vst1q_f32(p.add(i), quantize_v(xs, half, sv, lo, hi));
            i += W;
        }
        scalar::nearest_col(&mut col[blocks..], s, inv_s, qmin, qmax);
    }

    // SAFETY: caller must ensure NEON is available and the border slices
    // are at least `col.len()` long (engine layouts guarantee it).
    #[target_feature(enable = "neon")]
    pub unsafe fn quant_col_lin(
        col: &mut [f32],
        b0: &[f32],
        b1: &[f32],
        s: f32,
        inv_s: f32,
        qmin: f32,
        qmax: f32,
    ) {
        let (sv, iv) = (vdupq_n_f32(s), vdupq_n_f32(inv_s));
        let (lo, hi) = (vdupq_n_f32(qmin), vdupq_n_f32(qmax));
        let half = vdupq_n_f32(0.5);
        let n = col.len();
        let blocks = n / W * W;
        let p = col.as_mut_ptr();
        let mut i = 0;
        while i < blocks {
            let xs = vmulq_f32(vld1q_f32(p.add(i)), iv);
            let u = vaddq_f32(
                vmulq_f32(vld1q_f32(b1.as_ptr().add(i)), xs),
                vld1q_f32(b0.as_ptr().add(i)),
            );
            let border = vaddq_f32(half, fast_offset_v(u));
            vst1q_f32(p.add(i), quantize_v(xs, border, sv, lo, hi));
            i += W;
        }
        scalar::quant_col_lin(&mut col[blocks..], &b0[blocks..], &b1[blocks..], s, inv_s, qmin, qmax);
    }

    // SAFETY: caller must ensure NEON is available and the border slices
    // are at least `col.len()` long (engine layouts guarantee it).
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn quant_col_quad(
        col: &mut [f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        s: f32,
        inv_s: f32,
        qmin: f32,
        qmax: f32,
    ) {
        let (sv, iv) = (vdupq_n_f32(s), vdupq_n_f32(inv_s));
        let (lo, hi) = (vdupq_n_f32(qmin), vdupq_n_f32(qmax));
        let half = vdupq_n_f32(0.5);
        let n = col.len();
        let blocks = n / W * W;
        let p = col.as_mut_ptr();
        let mut i = 0;
        while i < blocks {
            let xs = vmulq_f32(vld1q_f32(p.add(i)), iv);
            let t = vaddq_f32(
                vmulq_f32(vld1q_f32(b2.as_ptr().add(i)), xs),
                vld1q_f32(b1.as_ptr().add(i)),
            );
            let u = vaddq_f32(vmulq_f32(t, xs), vld1q_f32(b0.as_ptr().add(i)));
            let border = vaddq_f32(half, fast_offset_v(u));
            vst1q_f32(p.add(i), quantize_v(xs, border, sv, lo, hi));
            i += W;
        }
        scalar::quant_col_quad(
            &mut col[blocks..],
            &b0[blocks..],
            &b1[blocks..],
            &b2[blocks..],
            s,
            inv_s,
            qmin,
            qmax,
        );
    }

    // SAFETY: caller must ensure NEON is available and all slices are at
    // least `xs.len()` long.
    #[target_feature(enable = "neon")]
    pub unsafe fn borders_col_lin(xs: &[f32], b0: &[f32], b1: &[f32], out: &mut [f32]) {
        let half = vdupq_n_f32(0.5);
        let n = xs.len();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            let x = vld1q_f32(xs.as_ptr().add(i));
            let u = vaddq_f32(
                vmulq_f32(vld1q_f32(b1.as_ptr().add(i)), x),
                vld1q_f32(b0.as_ptr().add(i)),
            );
            vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(half, fast_offset_v(u)));
            i += W;
        }
        scalar::borders_col_lin(&xs[blocks..], &b0[blocks..], &b1[blocks..], &mut out[blocks..]);
    }

    // SAFETY: caller must ensure NEON is available and all slices are at
    // least `xs.len()` long.
    #[target_feature(enable = "neon")]
    pub unsafe fn borders_col_quad(xs: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], out: &mut [f32]) {
        let half = vdupq_n_f32(0.5);
        let n = xs.len();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            let x = vld1q_f32(xs.as_ptr().add(i));
            let t = vaddq_f32(
                vmulq_f32(vld1q_f32(b2.as_ptr().add(i)), x),
                vld1q_f32(b1.as_ptr().add(i)),
            );
            let u = vaddq_f32(vmulq_f32(t, x), vld1q_f32(b0.as_ptr().add(i)));
            vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(half, fast_offset_v(u)));
            i += W;
        }
        scalar::borders_col_quad(
            &xs[blocks..],
            &b0[blocks..],
            &b1[blocks..],
            &b2[blocks..],
            &mut out[blocks..],
        );
    }

    // SAFETY: caller must ensure NEON is available and `dst` is at least
    // `src.len()` long.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale_col(src: &[f32], inv_s: f32, dst: &mut [f32]) {
        let iv = vdupq_n_f32(inv_s);
        let n = src.len();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            vst1q_f32(dst.as_mut_ptr().add(i), vmulq_f32(vld1q_f32(src.as_ptr().add(i)), iv));
            i += W;
        }
        scalar::scale_col(&src[blocks..], inv_s, &mut dst[blocks..]);
    }

    // SAFETY: caller must ensure NEON is available and `xs`/`borders`
    // are at least `col.len()` long.
    #[target_feature(enable = "neon")]
    pub unsafe fn round_col(
        col: &mut [f32],
        xs: &[f32],
        borders: &[f32],
        s: f32,
        qmin: f32,
        qmax: f32,
    ) {
        let sv = vdupq_n_f32(s);
        let (lo, hi) = (vdupq_n_f32(qmin), vdupq_n_f32(qmax));
        let n = col.len();
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            let x = vld1q_f32(xs.as_ptr().add(i));
            let b = vld1q_f32(borders.as_ptr().add(i));
            vst1q_f32(col.as_mut_ptr().add(i), quantize_v(x, b, sv, lo, hi));
            i += W;
        }
        scalar::round_col(&mut col[blocks..], &xs[blocks..], &borders[blocks..], s, qmin, qmax);
    }

    // SAFETY: caller must ensure NEON is available; `w`/`x` must be the
    // same length (debug-asserted).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(w: &[f32], x: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), x.len());
        let n = w.len();
        let mut acc = vdupq_n_f32(0.0);
        let blocks = n / W * W;
        let mut i = 0;
        while i < blocks {
            let wv = vld1q_f32(w.as_ptr().add(i));
            let xv = vld1q_f32(x.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(wv, xv));
            i += W;
        }
        // [a0+a2, a1+a3] then pairwise add — same tree as the scalar fold.
        let t = vadd_f32(vget_low_f32(acc), vget_high_f32(acc));
        let t2 = vpadd_f32(t, t);
        let mut sum = vget_lane_f32::<0>(t2);
        while i < n {
            sum += w[i] * x[i];
            i += 1;
        }
        sum
    }

    /// `dot`'s horizontal reduce: halves, then pairwise — matched by the
    /// scalar halving fold.
    // SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    unsafe fn hreduce(acc: float32x4_t) -> f32 {
        let t = vadd_f32(vget_low_f32(acc), vget_high_f32(acc));
        let t2 = vpadd_f32(t, t);
        vget_lane_f32::<0>(t2)
    }

    /// Vector transcription of `scalar::gemm_tile` (exact mode): one
    /// W-wide accumulator per output element, carried across strips,
    /// folded with `dot`'s tree, sequential ragged tail — bit-identical
    /// to the scalar tile and to `dot` per element (W == LANES here).
    // SAFETY: caller must ensure NEON is available; slice indexing stays
    // bounds-checked.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn gemm_tile(
        a: &[f32],
        mc: usize,
        m0: usize,
        mr: usize,
        bp: &[f32],
        nr: usize,
        k: usize,
        sums: &mut [f32],
    ) {
        debug_assert!(mr <= MR && nr <= NR && sums.len() >= mr * nr);
        let mut acc = [[vdupq_n_f32(0.0); NR]; MR];
        let (mut tab, mut tbb, mut tls, mut tvb) = (0usize, 0usize, 0usize, 0usize);
        let mut kbase = 0;
        while kbase < k {
            let ls = (k - kbase).min(KC);
            let abase = mc * kbase;
            let bbase = nr * kbase;
            let vb = ls / W * W;
            let mut t = 0;
            while t < vb {
                let mut av = [vdupq_n_f32(0.0); MR];
                for (mi, v) in av.iter_mut().enumerate().take(mr) {
                    *v = vld1q_f32(a.as_ptr().add(abase + (m0 + mi) * ls + t));
                }
                for ni in 0..nr {
                    let bv = vld1q_f32(bp.as_ptr().add(bbase + ni * ls + t));
                    for (mi, v) in av.iter().enumerate().take(mr) {
                        acc[mi][ni] = vaddq_f32(acc[mi][ni], vmulq_f32(*v, bv));
                    }
                }
                t += W;
            }
            (tab, tbb, tls, tvb) = (abase, bbase, ls, vb);
            kbase += ls;
        }
        for mi in 0..mr {
            for ni in 0..nr {
                let mut sum = hreduce(acc[mi][ni]);
                for t in tvb..tls {
                    sum += a[tab + (m0 + mi) * tls + t] * bp[tbb + ni * tls + t];
                }
                sums[mi * nr + ni] = sum;
            }
        }
    }

    /// FMA variant (opt-in `AQUANT_FAST=fma`): `vfmaq_f32` accumulation,
    /// same loop structure but relaxed rounding — allclose, NOT
    /// bit-identical. FMA is baseline on aarch64, so no extra detect.
    // SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn gemm_tile_fma(
        a: &[f32],
        mc: usize,
        m0: usize,
        mr: usize,
        bp: &[f32],
        nr: usize,
        k: usize,
        sums: &mut [f32],
    ) {
        debug_assert!(mr <= MR && nr <= NR && sums.len() >= mr * nr);
        let mut acc = [[vdupq_n_f32(0.0); NR]; MR];
        let (mut tab, mut tbb, mut tls, mut tvb) = (0usize, 0usize, 0usize, 0usize);
        let mut kbase = 0;
        while kbase < k {
            let ls = (k - kbase).min(KC);
            let abase = mc * kbase;
            let bbase = nr * kbase;
            let vb = ls / W * W;
            let mut t = 0;
            while t < vb {
                let mut av = [vdupq_n_f32(0.0); MR];
                for (mi, v) in av.iter_mut().enumerate().take(mr) {
                    *v = vld1q_f32(a.as_ptr().add(abase + (m0 + mi) * ls + t));
                }
                for ni in 0..nr {
                    let bv = vld1q_f32(bp.as_ptr().add(bbase + ni * ls + t));
                    for (mi, v) in av.iter().enumerate().take(mr) {
                        acc[mi][ni] = vfmaq_f32(acc[mi][ni], *v, bv);
                    }
                }
                t += W;
            }
            (tab, tbb, tls, tvb) = (abase, bbase, ls, vb);
            kbase += ls;
        }
        for mi in 0..mr {
            for ni in 0..nr {
                let mut sum = hreduce(acc[mi][ni]);
                for t in tvb..tls {
                    sum += a[tab + (m0 + mi) * tls + t] * bp[tbb + ni * tls + t];
                }
                sums[mi * nr + ni] = sum;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Public dispatchers. `*_on` takes an explicit backend (differential
// tests iterate `Backend::all()`); the plain names use `active()`.
// Safety: the SIMD arms are only sound when the backend's ISA is
// present — callers must pass a backend for which `available()` holds
// (debug-asserted here; `active()` guarantees it).
// ---------------------------------------------------------------------------

pub fn nearest_col_on(b: Backend, col: &mut [f32], s: f32, inv_s: f32, qmin: f32, qmax: f32) {
    debug_assert!(b.available());
    // SAFETY: each SIMD arm is cfg-gated to its ISA and callers uphold
    // the `b.available()` contract (asserted above; `active()` only
    // ever returns an available backend).
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::nearest_col(col, s, inv_s, qmin, qmax) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::nearest_col(col, s, inv_s, qmin, qmax) },
        _ => scalar::nearest_col(col, s, inv_s, qmin, qmax),
    }
}

pub fn nearest_col(col: &mut [f32], s: f32, inv_s: f32, qmin: f32, qmax: f32) {
    nearest_col_on(active(), col, s, inv_s, qmin, qmax)
}

#[allow(clippy::too_many_arguments)]
pub fn quant_col_lin_on(
    b: Backend,
    col: &mut [f32],
    b0: &[f32],
    b1: &[f32],
    s: f32,
    inv_s: f32,
    qmin: f32,
    qmax: f32,
) {
    debug_assert!(b.available());
    // SAFETY: each SIMD arm is cfg-gated to its ISA and callers uphold
    // the `b.available()` contract (asserted above; `active()` only
    // ever returns an available backend).
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::quant_col_lin(col, b0, b1, s, inv_s, qmin, qmax) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::quant_col_lin(col, b0, b1, s, inv_s, qmin, qmax) },
        _ => scalar::quant_col_lin(col, b0, b1, s, inv_s, qmin, qmax),
    }
}

pub fn quant_col_lin(col: &mut [f32], b0: &[f32], b1: &[f32], s: f32, inv_s: f32, qmin: f32, qmax: f32) {
    quant_col_lin_on(active(), col, b0, b1, s, inv_s, qmin, qmax)
}

#[allow(clippy::too_many_arguments)]
pub fn quant_col_quad_on(
    b: Backend,
    col: &mut [f32],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    s: f32,
    inv_s: f32,
    qmin: f32,
    qmax: f32,
) {
    debug_assert!(b.available());
    // SAFETY: each SIMD arm is cfg-gated to its ISA and callers uphold
    // the `b.available()` contract (asserted above; `active()` only
    // ever returns an available backend).
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::quant_col_quad(col, b0, b1, b2, s, inv_s, qmin, qmax) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::quant_col_quad(col, b0, b1, b2, s, inv_s, qmin, qmax) },
        _ => scalar::quant_col_quad(col, b0, b1, b2, s, inv_s, qmin, qmax),
    }
}

#[allow(clippy::too_many_arguments)]
pub fn quant_col_quad(
    col: &mut [f32],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    s: f32,
    inv_s: f32,
    qmin: f32,
    qmax: f32,
) {
    quant_col_quad_on(active(), col, b0, b1, b2, s, inv_s, qmin, qmax)
}

pub fn borders_col_lin_on(b: Backend, xs: &[f32], b0: &[f32], b1: &[f32], out: &mut [f32]) {
    debug_assert!(b.available());
    // SAFETY: each SIMD arm is cfg-gated to its ISA and callers uphold
    // the `b.available()` contract (asserted above; `active()` only
    // ever returns an available backend).
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::borders_col_lin(xs, b0, b1, out) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::borders_col_lin(xs, b0, b1, out) },
        _ => scalar::borders_col_lin(xs, b0, b1, out),
    }
}

pub fn borders_col_lin(xs: &[f32], b0: &[f32], b1: &[f32], out: &mut [f32]) {
    borders_col_lin_on(active(), xs, b0, b1, out)
}

pub fn borders_col_quad_on(b: Backend, xs: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], out: &mut [f32]) {
    debug_assert!(b.available());
    // SAFETY: each SIMD arm is cfg-gated to its ISA and callers uphold
    // the `b.available()` contract (asserted above; `active()` only
    // ever returns an available backend).
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::borders_col_quad(xs, b0, b1, b2, out) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::borders_col_quad(xs, b0, b1, b2, out) },
        _ => scalar::borders_col_quad(xs, b0, b1, b2, out),
    }
}

pub fn borders_col_quad(xs: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], out: &mut [f32]) {
    borders_col_quad_on(active(), xs, b0, b1, b2, out)
}

pub fn scale_col_on(b: Backend, src: &[f32], inv_s: f32, dst: &mut [f32]) {
    debug_assert!(b.available());
    // SAFETY: each SIMD arm is cfg-gated to its ISA and callers uphold
    // the `b.available()` contract (asserted above; `active()` only
    // ever returns an available backend).
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::scale_col(src, inv_s, dst) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::scale_col(src, inv_s, dst) },
        _ => scalar::scale_col(src, inv_s, dst),
    }
}

pub fn scale_col(src: &[f32], inv_s: f32, dst: &mut [f32]) {
    scale_col_on(active(), src, inv_s, dst)
}

pub fn round_col_on(
    b: Backend,
    col: &mut [f32],
    xs: &[f32],
    borders: &[f32],
    s: f32,
    qmin: f32,
    qmax: f32,
) {
    debug_assert!(b.available());
    // SAFETY: each SIMD arm is cfg-gated to its ISA and callers uphold
    // the `b.available()` contract (asserted above; `active()` only
    // ever returns an available backend).
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::round_col(col, xs, borders, s, qmin, qmax) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::round_col(col, xs, borders, s, qmin, qmax) },
        _ => scalar::round_col(col, xs, borders, s, qmin, qmax),
    }
}

pub fn round_col(col: &mut [f32], xs: &[f32], borders: &[f32], s: f32, qmin: f32, qmax: f32) {
    round_col_on(active(), col, xs, borders, s, qmin, qmax)
}

pub fn dot_on(b: Backend, w: &[f32], x: &[f32]) -> f32 {
    debug_assert!(b.available());
    // SAFETY: each SIMD arm is cfg-gated to its ISA and callers uphold
    // the `b.available()` contract (asserted above; `active()` only
    // ever returns an available backend).
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot(w, x) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot(w, x) },
        _ => scalar::dot(w, x),
    }
}

pub fn dot(w: &[f32], x: &[f32]) -> f32 {
    dot_on(active(), w, x)
}

/// One `mr x nr` register tile of the packed GEMM (layouts documented
/// on `scalar::gemm_tile` and in `nn/im2col.rs`). Exact mode is
/// bit-identical across backends and to the `dot`-per-row reference;
/// `FastMode::Fma` is the opt-in relaxed path.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tile_on(
    b: Backend,
    fast: FastMode,
    a: &[f32],
    mc: usize,
    m0: usize,
    mr: usize,
    bp: &[f32],
    nr: usize,
    k: usize,
    sums: &mut [f32],
) {
    debug_assert!(b.available());
    // SAFETY: every SIMD arm is cfg-gated to its ISA and the asserted
    // `b.available()` contract holds at every call site; the AVX2 FMA
    // arm additionally requires the FMA extension, checked by its match
    // guard (without it the request falls through to the exact AVX2
    // kernel, so an FMA-less Haswell predecessor never executes vfmadd).
    match (b, fast) {
        #[cfg(target_arch = "x86_64")]
        (Backend::Avx2, FastMode::Fma) if is_x86_feature_detected!("fma") => unsafe {
            avx2::gemm_tile_fma(a, mc, m0, mr, bp, nr, k, sums)
        },
        #[cfg(target_arch = "x86_64")]
        (Backend::Avx2, _) => unsafe { avx2::gemm_tile(a, mc, m0, mr, bp, nr, k, sums) },
        #[cfg(target_arch = "aarch64")]
        (Backend::Neon, FastMode::Fma) => unsafe {
            neon::gemm_tile_fma(a, mc, m0, mr, bp, nr, k, sums)
        },
        // SAFETY: NEON is baseline on aarch64 (cfg-gated arm).
        #[cfg(target_arch = "aarch64")]
        (Backend::Neon, _) => unsafe { neon::gemm_tile(a, mc, m0, mr, bp, nr, k, sums) },
        _ => scalar::gemm_tile(a, mc, m0, mr, bp, nr, k, fast == FastMode::Fma, sums),
    }
}

/// `gemm_tile_on` with the process-wide backend and fast mode.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tile(
    a: &[f32],
    mc: usize,
    m0: usize,
    mr: usize,
    bp: &[f32],
    nr: usize,
    k: usize,
    sums: &mut [f32],
) {
    gemm_tile_on(active(), fast_mode(), a, mc, m0, mr, bp, nr, k, sums)
}

/// Contiguous im2col row gather (the interior fast path copies whole
/// k-wide rows instead of testing bounds per element). `copy_from_slice`
/// lowers to memcpy, which every libc vectorizes — no per-ISA variant.
#[inline(always)]
pub fn gather_row(dst: &mut [f32], src: &[f32]) {
    dst.copy_from_slice(src);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_backend_always_available() {
        assert!(Backend::Scalar.available());
        assert!(Backend::best().available());
        assert!(active().available());
    }

    #[test]
    fn dot_matches_sequential_for_short_inputs() {
        // below one lane block the fold is a plain sequential sum
        let w = [1.5f32, -2.0, 0.25];
        let x = [2.0f32, 0.5, 4.0];
        let want = 1.5 * 2.0 + -2.0 * 0.5 + 0.25 * 4.0;
        assert_eq!(scalar::dot(&w, &x), want);
    }

    #[test]
    fn scalar_gemm_tile_matches_dot_bitwise() {
        // Pack row-major rows into the KC-strip layout gemm_tile reads.
        fn pack_strips(rows: &[Vec<f32>], k: usize) -> Vec<f32> {
            let mc = rows.len();
            let mut out = vec![0.0; mc * k];
            let mut kbase = 0;
            while kbase < k {
                let ls = (k - kbase).min(KC);
                for (mi, row) in rows.iter().enumerate() {
                    out[mc * kbase + mi * ls..mc * kbase + (mi + 1) * ls]
                        .copy_from_slice(&row[kbase..kbase + ls]);
                }
                kbase += ls;
            }
            out
        }
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut nextf = || {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((state >> 33) as f32) / ((1u64 << 31) as f32) - 0.5
        };
        for &k in &[1usize, 3, LANES, KC - 1, KC, KC + 1, 2 * KC + 5] {
            let a_rows: Vec<Vec<f32>> =
                (0..3).map(|_| (0..k).map(|_| nextf()).collect()).collect();
            let b_rows: Vec<Vec<f32>> =
                (0..2).map(|_| (0..k).map(|_| nextf()).collect()).collect();
            let ap = pack_strips(&a_rows, k);
            let bp = pack_strips(&b_rows, k);
            let mut sums = [0.0f32; MR * NR];
            scalar::gemm_tile(&ap, 3, 0, 3, &bp, 2, k, false, &mut sums);
            for (mi, arow) in a_rows.iter().enumerate() {
                for (ni, brow) in b_rows.iter().enumerate() {
                    let want = scalar::dot(brow, arow);
                    assert_eq!(
                        sums[mi * 2 + ni].to_bits(),
                        want.to_bits(),
                        "k={k} mi={mi} ni={ni}"
                    );
                }
            }
        }
    }

    #[test]
    fn fast_offset_is_odd_and_bounded() {
        for i in 0..1000 {
            let u = (i as f32 - 500.0) * 0.02;
            let v = fast_offset(u);
            assert!(v.abs() <= 0.5, "offset {v} out of range at u={u}");
            assert_eq!(v, -fast_offset(-u));
        }
    }
}
