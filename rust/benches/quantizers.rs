//! Micro-benchmarks of the quantization substrate: nearest rounding,
//! border-function evaluation (element-wise / fused / quadratic), the
//! A-rounding flip algorithm (Table 1's "impractical" scheme — measured
//! here to substantiate that claim), and activation scale search.

use aquant::quant::arounding::around_column;
use aquant::quant::border::BorderFn;
use aquant::quant::scale_search::search_scale;
use aquant::util::bench::{bench, default_budget};
use aquant::util::rng::Rng;

fn main() {
    let budget = default_budget();
    let mut rng = Rng::new(42);
    let rows = 32 * 9; // a typical mid-layer im2col column
    let k2 = 9;
    let col: Vec<f32> = (0..rows).map(|_| rng.range_f32(0.0, 3.0)).collect();
    let params: Vec<f32> = (0..rows * 4).map(|_| rng.range_f32(-0.5, 0.5)).collect();

    println!("quantizer micro-benches (one {rows}-row im2col column)");
    let nearest = BorderFn::nearest(rows, k2);
    let mut scratch = Vec::new();
    let mut buf = col.clone();
    let r = bench("nearest/column", budget, || {
        buf.copy_from_slice(&col);
        nearest.quant_column(&mut buf, 0.1, 0.0, 15.0, &mut scratch);
    });
    println!("{}", r.row());

    for (label, fuse, b2) in [
        ("border-elem-linear", false, false),
        ("border-elem-quadratic", false, true),
        ("border-fused-quadratic", true, true),
    ] {
        let b = BorderFn::from_params(params.clone(), k2, fuse, b2).unwrap();
        let r = bench(&format!("{label}/column"), budget, || {
            buf.copy_from_slice(&col);
            b.quant_column(&mut buf, 0.1, 0.0, 15.0, &mut scratch);
        });
        println!("{}", r.row());
    }

    let r = bench("arounding/column", budget, || {
        buf.copy_from_slice(&col);
        around_column(&mut buf, 0.1, 0.0, 15.0, k2);
    });
    println!("{}", r.row());

    let sample: Vec<f32> = (0..4096).map(|_| rng.range_f32(0.0, 4.0)).collect();
    let r = bench("scale-search/4096x60", budget, || {
        let _ = search_scale(&sample, 0.0, 15.0, 60);
    });
    println!("{}", r.row());
}
