//! Figure 3 bench: per-model conv latency with the border function
//! original (no act quant) vs fused into im2col vs unfused (second pass).
//!
//! Uses the in-tree harness (criterion is unavailable offline); run with
//! `cargo bench --offline` after `make artifacts`.

use aquant::config::Bits;
use aquant::coordinator::state::bits_row_for;
use aquant::exp::cell::Ctx;
use aquant::nn::engine::{ActQuant, Engine, FusionMode};
use aquant::quant::border::BorderFn;
use aquant::util::bench::{bench, default_budget};

fn main() {
    let Ok(ctx) = Ctx::new("artifacts", None) else {
        eprintln!("conv_latency: artifacts/ missing — run `make artifacts` first. Skipping.");
        return;
    };
    let budget = default_budget();
    let bits = Bits { w: 32, a: 4 };
    println!("Figure 3 latency bench (per-image forward, µs)");
    for model in ctx.models() {
        let topo = ctx.topo(&model).unwrap().clone();
        let weights = ctx.weights(&model).unwrap().clone();
        let image = ctx.dataset.test.image(0).to_vec();
        for (label, mode) in [
            ("original", None),
            ("fused", Some(FusionMode::Fused)),
            ("unfused", Some(FusionMode::Unfused)),
        ] {
            let mut eng = Engine::new(topo.clone(), weights.clone());
            if let Some(m) = mode {
                eng.fusion = m;
                for l in topo.all_layers() {
                    let row = bits_row_for(&topo, bits, &l.name);
                    let params = vec![0.05f32; l.rows * 4];
                    eng.set_act_quant(
                        &l.name,
                        ActQuant::Border {
                            border: BorderFn::from_params(params, l.k2(), true, true).unwrap(),
                            s: 0.05,
                            qmin: row.qmin_a,
                            qmax: row.qmax_a,
                        },
                    );
                }
            }
            let r = bench(&format!("{model}/forward/{label}"), budget, || {
                let _ = eng.forward(&image, None).unwrap();
            });
            println!("{}", r.row());
        }
    }
}
