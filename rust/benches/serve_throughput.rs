//! Serving-path throughput: pooled classification at worker counts
//! 1/2/4 and batch sizes 1/8/64 over a synthetic model with learned
//! borders on every layer (the serving hot loop), plus a mixed-model
//! row — tiny and bench batches interleaved through ONE shared pool,
//! the multi-model serving shape the fair scheduler admits into — and
//! a high-connection-count row: 256 concurrent TCP clients pipelining
//! requests through the readiness event loop end to end (sockets,
//! decode, queue, scheduler, pool, response writes), and a
//! reload-under-load row — the same burst with control-plane registry
//! swaps landing mid-flight, pricing the epoch machinery.
//!
//! Prints human rows plus a machine-readable JSON blob; set
//! `BENCH_JSON=path` to write the blob to a file instead
//! (`scripts/bench_check.sh` uses this to emit BENCH_serve.json, guard
//! the 4-worker speedup floor, and track the mixed + 256-connection
//! rows in `bench_history/`).

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use aquant::config::ServeConfig;
use aquant::nn::im2col;
use aquant::nn::kernels;
use aquant::nn::pool::{InferencePool, IntraCfg};
use aquant::nn::registry::ModelRegistry;
use aquant::nn::synth;
use aquant::util::bench::{bench, default_budget};
use aquant::util::rng::Rng;

fn main() {
    let budget = default_budget();
    let mut rng = Rng::new(42);
    let (topo, weights) = synth::bench_model(&mut rng);
    let engine = Arc::new(synth::engine_with_random_borders(
        &topo, &weights, &mut rng, true, true,
    ));
    let img_elems = engine.img_elems();
    let max_batch = 64usize;
    let images: Vec<f32> = (0..max_batch * img_elems)
        .map(|_| rng.range_f32(-1.0, 3.0))
        .collect();

    println!(
        "serve throughput: model {} ({} f32/image), pooled classify",
        engine.topo.name, img_elems
    );
    // (workers, batch, images_per_sec, median_us)
    let mut rows: Vec<(usize, usize, f64, f64)> = Vec::new();
    for workers in [1usize, 2, 4] {
        let pool = InferencePool::with_scratch_dims(workers, engine.scratch_dims());
        for batch in [1usize, 8, 64] {
            // pre-flattened batch: the timed loop measures pooled
            // inference (an Arc clone is free), not buffer copying,
            // so the speedup guard isn't diluted by memcpy
            let flat = Arc::new(images[..batch * img_elems].to_vec());
            let r = bench(&format!("pool/workers{workers}/batch{batch}"), budget, || {
                let preds = pool.classify_flat(&engine, flat.clone(), batch).unwrap();
                std::hint::black_box(preds);
            });
            let ips = batch as f64 / r.median.as_secs_f64();
            println!("{}  {:>12.0} images/s", r.row(), ips);
            rows.push((workers, batch, ips, r.median.as_secs_f64() * 1e6));
        }
    }

    let ips = |w: usize, b: usize| rows.iter().find(|r| r.0 == w && r.1 == b).unwrap().2;
    let speedup = ips(4, 64) / ips(1, 64);
    println!("speedup workers 4 vs 1 @ batch 64: {speedup:.2}x");

    // Mixed-model row: a 32-image tiny batch AND a 32-image bench batch
    // submitted concurrently (non-blocking `submit`, awaited together)
    // through ONE 4-worker pool sized for both models (registry
    // max-dims scratch) — the shape weighted multi-model admission
    // produces, with shards of both models genuinely interleaved across
    // the workers. Tracks cross-model scratch reshaping and dispatch
    // overhead that single-model rows (and back-to-back blocking calls)
    // can't see.
    let tiny = Arc::new(synth::engine_from_spec("tiny", 42).expect("tiny spec"));
    let mixed_ips = {
        let registry = ModelRegistry::new(vec![
            ("tiny".into(), tiny.clone()),
            ("bench".into(), engine.clone()),
        ])
        .expect("mixed registry");
        let pool = InferencePool::for_registry(4, &registry);
        let mixed_batch = 32usize;
        let tiny_imgs: Vec<f32> = (0..mixed_batch * tiny.img_elems())
            .map(|_| rng.range_f32(-1.0, 3.0))
            .collect();
        let tiny_flat = Arc::new(tiny_imgs);
        let bench_flat = Arc::new(images[..mixed_batch * img_elems].to_vec());
        let r = bench("pool/mixed2/batch32+32", budget, || {
            let (tx, rx) = std::sync::mpsc::channel();
            let t = tx.clone();
            pool.submit(
                0,
                &tiny,
                tiny_flat.clone(),
                mixed_batch,
                Box::new(move |r| {
                    let _ = t.send(r);
                }),
            )
            .unwrap();
            pool.submit(
                1,
                &engine,
                bench_flat.clone(),
                mixed_batch,
                Box::new(move |r| {
                    let _ = tx.send(r);
                }),
            )
            .unwrap();
            let a = rx.recv().unwrap().unwrap();
            let b = rx.recv().unwrap().unwrap();
            std::hint::black_box((a, b));
        });
        let ips = (2 * mixed_batch) as f64 / r.median.as_secs_f64();
        println!("{}  {:>12.0} images/s (2 models, concurrent)", r.row(), ips);
        ips
    };

    // High-connection-count row: 256 concurrent clients against a real
    // event-loop server (tiny model, so the wire layer — not the
    // matmuls — dominates). Every client pipelines `reqs` 8-image
    // requests; 8 driver threads multiplex 32 connections each, so
    // all 256 connections are genuinely concurrent while the server
    // side runs them on ONE readiness loop. Wall clock over the whole
    // burst → images/sec.
    let (conns_ips, p99_service_us) = {
        let conns = 256usize;
        let driver_threads = 8usize;
        let reqs = 4usize;
        let batch = 8usize;
        let tiny_srv = Arc::new(synth::engine_from_spec("tiny", 42).expect("tiny spec"));
        let elems = tiny_srv.img_elems();
        let cfg = ServeConfig {
            workers: 4,
            max_batch: 64,
            batch_wait_us: 200,
            max_accepts: Some(conns),
            ..ServeConfig::default()
        };
        let srv = aquant::server::Server::bind_single(tiny_srv, "127.0.0.1:0", cfg)
            .expect("bind bench server");
        let addr = srv.local_addr().expect("addr");
        let stats = srv.stats(); // outlives run(): read p99 after the join
        let server = std::thread::spawn(move || srv.run());
        let payload: Vec<u8> = {
            let imgs: Vec<f32> = (0..batch * elems).map(|_| rng.range_f32(-1.0, 3.0)).collect();
            let mut req = (batch as u32).to_le_bytes().to_vec();
            for v in &imgs {
                req.extend_from_slice(&v.to_le_bytes());
            }
            req
        };
        let t0 = Instant::now();
        let mut drivers = Vec::new();
        for _ in 0..driver_threads {
            let per = conns / driver_threads;
            let payload = payload.clone();
            drivers.push(std::thread::spawn(move || {
                let mut socks: Vec<std::net::TcpStream> = (0..per)
                    .map(|_| std::net::TcpStream::connect(addr).expect("connect"))
                    .collect();
                // write everything first: all connections in flight at once
                for s in socks.iter_mut() {
                    for _ in 0..reqs {
                        s.write_all(&payload).expect("request");
                    }
                }
                for s in socks.iter_mut() {
                    for _ in 0..reqs {
                        use std::io::Read as _;
                        let mut hdr = [0u8; 4];
                        s.read_exact(&mut hdr).expect("response header");
                        let m = u32::from_le_bytes(hdr) as usize;
                        assert_eq!(m, batch, "short response");
                        let mut buf = vec![0u8; m * 4];
                        s.read_exact(&mut buf).expect("response body");
                    }
                }
            }));
        }
        for d in drivers {
            d.join().expect("driver");
        }
        let wall = t0.elapsed();
        server.join().expect("server thread").expect("serve ok");
        let total = (conns * reqs * batch) as f64;
        let ips = total / wall.as_secs_f64();
        // tail latency of the engine batches this burst produced, from
        // the same histogram /stats serves (log2 buckets, so ~2x
        // resolution — regression gating wants the trend, not the digit)
        let p99 = stats
            .model(0)
            .expect("default model")
            .service_hist
            .quantile(0.99)
            .unwrap_or(0.0);
        println!(
            "serve/conns256/pipelined {:>10.1}ms {:>12.0} images/s \
             (256 conns, one event loop, batch-service p99 {:.0}us)",
            wall.as_secs_f64() * 1e3,
            ips,
            p99
        );
        (ips, p99)
    };

    // Reload-under-load row: the same 256-connection pipelined burst,
    // but with the control plane landing registry swaps (policy
    // retunes, a hot add, a remove, reloads) while the burst drains.
    // Every swap publishes a fresh epoch the event loop picks up
    // between requests; the delta vs the conns256 row is the epoch
    // machinery's cost on the hot path.
    let reload_ips = {
        let conns = 256usize;
        let driver_threads = 8usize;
        let reqs = 4usize;
        let batch = 8usize;
        let tiny_srv = Arc::new(synth::engine_from_spec("tiny", 42).expect("tiny spec"));
        let elems = tiny_srv.img_elems();
        let cfg = ServeConfig {
            workers: 4,
            max_batch: 64,
            batch_wait_us: 200,
            max_accepts: Some(conns),
            admin_addr: Some("127.0.0.1:0".into()),
            ..ServeConfig::default()
        };
        let registry =
            ModelRegistry::new(vec![("tiny".into(), tiny_srv)]).expect("reload bench registry");
        let srv = aquant::server::Server::bind(Arc::new(registry), "127.0.0.1:0", cfg)
            .expect("bind reload bench server");
        let addr = srv.local_addr().expect("addr");
        let admin_addr = srv.admin_local_addr().expect("admin addr");
        let server = std::thread::spawn(move || srv.run());
        let payload: Vec<u8> = {
            let imgs: Vec<f32> = (0..batch * elems).map(|_| rng.range_f32(-1.0, 3.0)).collect();
            let mut req = (batch as u32).to_le_bytes().to_vec();
            for v in &imgs {
                req.extend_from_slice(&v.to_le_bytes());
            }
            req
        };
        let t0 = Instant::now();
        let mut drivers = Vec::new();
        for _ in 0..driver_threads {
            let per = conns / driver_threads;
            let payload = payload.clone();
            drivers.push(std::thread::spawn(move || {
                let mut socks: Vec<std::net::TcpStream> = (0..per)
                    .map(|_| std::net::TcpStream::connect(addr).expect("connect"))
                    .collect();
                for s in socks.iter_mut() {
                    for _ in 0..reqs {
                        s.write_all(&payload).expect("request");
                    }
                }
                for s in socks.iter_mut() {
                    for _ in 0..reqs {
                        use std::io::Read as _;
                        let mut hdr = [0u8; 4];
                        s.read_exact(&mut hdr).expect("response header");
                        let m = u32::from_le_bytes(hdr) as usize;
                        assert_eq!(m, batch, "short response under reload");
                        let mut buf = vec![0u8; m * 4];
                        s.read_exact(&mut buf).expect("response body");
                    }
                }
            }));
        }
        // Control-plane churn concurrent with the burst; every command
        // must succeed (a failed swap would mean the row measured
        // nothing).
        let mut admin = std::net::TcpStream::connect(admin_addr).expect("admin connect");
        let mut swaps = 0usize;
        for cmd in [
            "policy tiny weight=2",
            "reload",
            "add spare=synth:tiny:77",
            "policy tiny weight=1",
            "reload",
            "remove spare",
        ] {
            use std::io::Read as _;
            admin.write_all(cmd.as_bytes()).expect("admin write");
            admin.write_all(b"\n").expect("admin write");
            let mut reply = Vec::new();
            let mut b = [0u8; 1];
            loop {
                admin.read_exact(&mut b).expect("admin reply");
                if b[0] == b'\n' {
                    break;
                }
                reply.push(b[0]);
            }
            assert!(
                reply.starts_with(b"ok"),
                "admin {cmd:?} failed: {}",
                String::from_utf8_lossy(&reply)
            );
            swaps += 1;
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        for d in drivers {
            d.join().expect("reload driver");
        }
        let wall = t0.elapsed();
        drop(admin);
        server.join().expect("server thread").expect("serve ok");
        let ips = (conns * reqs * batch) as f64 / wall.as_secs_f64();
        println!(
            "serve/reload-under-load  {:>10.1}ms {:>12.0} images/s \
             (256 conns, {swaps} registry swaps mid-burst)",
            wall.as_secs_f64() * 1e3,
            ips
        );
        ips
    };

    // Router-tier row: the same pipelined wire shape pushed through a
    // front-end router — two backend event-loop servers (both hosting
    // ids 0 and 1; traffic partitioned by the route table) behind one
    // router forwarding frames verbatim over pooled, pipelined backend
    // connections. Wall clock over the burst → images/sec; the delta
    // vs serving directly is the router hop's cost.
    let router_ips = {
        use aquant::config::RouteSpec;
        let conns = 32usize;
        let driver_threads = 4usize;
        let reqs = 4usize;
        let batch = 8usize;
        let pool = 2usize;
        let ta = Arc::new(synth::engine_from_spec("tiny", 42).expect("tiny spec"));
        let tb = Arc::new(synth::engine_from_spec("tiny", 43).expect("tiny spec"));
        let elems = ta.img_elems();
        let backend_cfg = ServeConfig {
            workers: 2,
            max_batch: 64,
            batch_wait_us: 200,
            max_accepts: Some(pool),
            ..ServeConfig::default()
        };
        let mut backends = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..2 {
            let registry = ModelRegistry::new(vec![
                ("a".into(), ta.clone()),
                ("b".into(), tb.clone()),
            ])
            .expect("backend registry");
            let srv = aquant::server::Server::bind(
                Arc::new(registry),
                "127.0.0.1:0",
                backend_cfg.clone(),
            )
            .expect("bind backend");
            addrs.push(srv.local_addr().expect("backend addr"));
            backends.push(std::thread::spawn(move || srv.run()));
        }
        let router_cfg = ServeConfig {
            route_pool: pool,
            route_inflight: 32,
            max_accepts: Some(conns),
            ..ServeConfig::default()
        };
        let routes = vec![
            RouteSpec {
                name: "a".into(),
                addr: addrs[0].to_string(),
            },
            RouteSpec {
                name: "b".into(),
                addr: addrs[1].to_string(),
            },
        ];
        let srv = aquant::server::RouterServer::bind(routes, "127.0.0.1:0", router_cfg)
            .expect("bind router");
        let raddr = srv.local_addr().expect("router addr");
        let router = std::thread::spawn(move || srv.run());
        // v1 frames route to id 0 (backend A), v2 id-1 frames to
        // backend B — alternating per connection, so both backends see
        // half the burst concurrently
        let imgs: Vec<f32> = (0..batch * elems).map(|_| rng.range_f32(-1.0, 3.0)).collect();
        let mut v1 = (batch as u32).to_le_bytes().to_vec();
        let mut v2 = aquant::server::encode_header_v2(1, batch as u32).to_vec();
        for v in &imgs {
            v1.extend_from_slice(&v.to_le_bytes());
            v2.extend_from_slice(&v.to_le_bytes());
        }
        let t0 = Instant::now();
        let mut drivers = Vec::new();
        for d in 0..driver_threads {
            let per = conns / driver_threads;
            let (v1, v2) = (v1.clone(), v2.clone());
            drivers.push(std::thread::spawn(move || {
                let mut socks: Vec<std::net::TcpStream> = (0..per)
                    .map(|_| std::net::TcpStream::connect(raddr).expect("connect router"))
                    .collect();
                for (c, s) in socks.iter_mut().enumerate() {
                    let payload = if (d * per + c) % 2 == 0 { &v1 } else { &v2 };
                    for _ in 0..reqs {
                        s.write_all(payload).expect("request");
                    }
                }
                for s in socks.iter_mut() {
                    for _ in 0..reqs {
                        use std::io::Read as _;
                        let mut hdr = [0u8; 4];
                        s.read_exact(&mut hdr).expect("response header");
                        let m = u32::from_le_bytes(hdr) as usize;
                        assert_eq!(m, batch, "short response via router");
                        let mut buf = vec![0u8; m * 4];
                        s.read_exact(&mut buf).expect("response body");
                    }
                }
            }));
        }
        for d in drivers {
            d.join().expect("router driver");
        }
        let wall = t0.elapsed();
        router.join().expect("router thread").expect("route ok");
        for b in backends {
            b.join().expect("backend thread").expect("serve ok");
        }
        let ips = (conns * reqs * batch) as f64 / wall.as_secs_f64();
        println!(
            "serve/router2/pipelined  {:>10.1}ms {:>12.0} images/s \
             ({conns} conns through 1 router -> 2 backends)",
            wall.as_secs_f64() * 1e3,
            ips
        );
        ips
    };

    // Kernel microbenches, tagged with the active SIMD backend: the
    // border quantize-dequantize column pass (ns per 4096-row column)
    // and the packed-panel tiled GEMM (GFLOP/s on a conv-shaped
    // 196x32x288 problem) in both accuracy modes — exact (the
    // bit-identity default) and the opt-in relaxed FMA kernels.
    let kernel_backend = kernels::active().name();
    let gemm_tile = format!(
        "mr{}xnr{}xkc{}",
        kernels::MR,
        kernels::NR,
        kernels::KC
    );
    let (border_quant_col_ns, gemm_gflops, gemm_gflops_fma) = {
        let n = 4096usize;
        let col: Vec<f32> = (0..n).map(|_| rng.range_f32(-4.0, 4.0)).collect();
        let b0: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b1: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b2: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut buf = col.clone();
        let r = bench(&format!("kernels/{kernel_backend}/quant_col_quad4096"), budget, || {
            buf.copy_from_slice(&col);
            kernels::quant_col_quad(&mut buf, &b0, &b1, &b2, 0.1, 10.0, 0.0, 15.0);
            std::hint::black_box(&buf);
        });
        let border_ns = r.median.as_secs_f64() * 1e9;
        println!("{}  {:>12.1} ns/column", r.row(), border_ns);
        // A mid-network conv shape: 32->32 channels, 3x3, 14x14 output
        // (np = 196 pixels, rows = 288), the tile sizes' home turf.
        use aquant::nn::topology::LayerTopo;
        let (ic, oc, k, hw) = (32usize, 32usize, 3usize, 14usize);
        let l = LayerTopo {
            name: "gemm-bench".into(),
            kind: "conv".into(),
            ic,
            oc,
            k,
            stride: 1,
            pad: 1,
            groups: 1,
            relu: false,
            gap_input: false,
            rows: ic * k * k,
            in_chw: (ic, hw, hw),
            out_chw: (oc, hw, hw),
        };
        let np = hw * hw;
        let wts: Vec<f32> = (0..oc * l.rows).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let bias = vec![0.0f32; oc];
        let patches: Vec<f32> = (0..np * l.rows).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let pg = im2col::pack_weights(&l, &wts);
        let mut apanel = vec![0.0f32; np * l.rows];
        im2col::pack_patches(&l, &patches, &mut apanel);
        let nt = im2col::n_panels(&l);
        let flops = 2.0 * (oc * np * l.rows) as f64;
        let mut out = vec![0.0f32; oc * np];
        let mut gf = [0.0f64; 2];
        for (i, fast) in [kernels::FastMode::Exact, kernels::FastMode::Fma]
            .into_iter()
            .enumerate()
        {
            let r = bench(
                &format!("kernels/{kernel_backend}/gemm_{gemm_tile}/{}", fast.name()),
                budget,
                || {
                    im2col::gemm_panels_on(
                        kernels::active(),
                        fast,
                        &l,
                        &pg,
                        &bias,
                        &apanel,
                        &mut out,
                        0,
                        nt,
                    );
                    std::hint::black_box(&out);
                },
            );
            gf[i] = flops / r.median.as_secs_f64() / 1e9;
            println!("{}  {:>12.2} GFLOP/s", r.row(), gf[i]);
        }
        (border_ns, gf[0], gf[1])
    };

    // Single-image p99 is the latency intra-image sharding exists for:
    // the same 4-worker pool, batch 1, with conv-phase chunking off and
    // forced on (threshold 0 so every layer shards).
    let (single_img_serial_us, single_img_intra_us) = {
        let flat = Arc::new(images[..img_elems].to_vec());
        let mut med = [0.0f64; 2];
        for (i, intra) in [None, Some(IntraCfg { split: 0, min_elems: 0 })]
            .into_iter()
            .enumerate()
        {
            let label = if intra.is_some() { "intra" } else { "serial" };
            let pool = InferencePool::with_intra(4, engine.scratch_dims(), 1, intra);
            let r = bench(&format!("pool/single-image/{label}"), budget, || {
                let preds = pool.classify_flat(&engine, flat.clone(), 1).unwrap();
                std::hint::black_box(preds);
            });
            med[i] = r.median.as_secs_f64() * 1e6;
            println!("{}", r.row());
        }
        println!(
            "single-image speedup intra vs serial: {:.2}x",
            med[0] / med[1].max(1e-9)
        );
        (med[0], med[1])
    };

    let mut json = String::from("{\n  \"bench\": \"serve_throughput\",\n  \"backend\": \"rust\",\n");
    json.push_str(&format!("  \"kernel_backend\": \"{kernel_backend}\",\n"));
    json.push_str(&format!("  \"gemm_tile\": \"{gemm_tile}\",\n"));
    json.push_str("  \"rows\": [\n");
    for (i, (w, b, v, us)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {w}, \"batch\": {b}, \"images_per_sec\": {v:.1}, \
             \"median_us\": {us:.1}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str(&format!(
        "  ],\n  \"mixed_w4_b32x2_images_per_sec\": {mixed_ips:.1},\n  \
         \"conns256_images_per_sec\": {conns_ips:.1},\n  \
         \"reload_under_load_images_per_sec\": {reload_ips:.1},\n  \
         \"router_images_per_sec\": {router_ips:.1},\n  \
         \"p99_service_us\": {p99_service_us:.1},\n  \
         \"border_quant_col_ns\": {border_quant_col_ns:.1},\n  \
         \"gemm_gflops\": {gemm_gflops:.3},\n  \
         \"gemm_gflops_fma\": {gemm_gflops_fma:.3},\n  \
         \"single_img_serial_us\": {single_img_serial_us:.1},\n  \
         \"single_img_intra_us\": {single_img_intra_us:.1},\n  \
         \"speedup_w4_vs_w1_b64\": {speedup:.3}\n}}\n"
    ));
    match std::env::var("BENCH_JSON") {
        Ok(path) if !path.is_empty() => {
            std::fs::write(&path, &json).expect("write BENCH_JSON");
            eprintln!("wrote {path}");
        }
        _ => println!("{json}"),
    }
}
