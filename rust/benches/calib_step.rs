//! Calibration-loop benches: the full-model quantized forward and the
//! per-layer quantized forward (the PJRT hot paths bounding every
//! accuracy table's wall-clock).

use aquant::config::{Bits, Method, RunConfig};
use aquant::coordinator::chain::QuantCtx;
use aquant::coordinator::state::Knobs;
use aquant::exp::cell::Ctx;
use aquant::quant::tensor::Tensor;
use aquant::util::bench::{bench, default_budget};

fn main() {
    let Ok(ctx) = Ctx::new("artifacts", Some(2)) else {
        eprintln!("calib_step: artifacts/ missing — run `make artifacts` first. Skipping.");
        return;
    };
    let budget = default_budget();
    let model = "mobiles".to_string();
    let bits = Bits { w: 2, a: 2 };
    let cfg = RunConfig::new(&model, Method::AQuant, bits);
    let st = ctx.calibrated_state(&cfg).expect("calibrate");
    let chain = ctx.chain(&model).expect("chain");
    let b = chain.batch;
    let d = &ctx.dataset.calib;
    let idx: Vec<usize> = (0..b).collect();
    let x = Tensor::new(vec![b, d.c, d.h, d.w], d.gather(&idx)).unwrap();

    let q = QuantCtx {
        state: &st,
        bits,
        knobs: Knobs::inference(Method::AQuant, bits),
    };
    // warm the executable cache
    let _ = chain.full(&x, Some(&q)).unwrap();
    let r = bench("q_full/batch32 (pallas border kernel)", budget, || {
        let _ = chain.full(&x, Some(&q)).unwrap();
    });
    println!("{}", r.row());
    let _ = chain.full(&x, None).unwrap();
    let r = bench("fp_full/batch32", budget, || {
        let _ = chain.full(&x, None).unwrap();
    });
    println!("{}", r.row());
    let topo = ctx.topo(&model).unwrap();
    let l = &topo.blocks[1].layers[0];
    let tap = chain.walk(&x, None).unwrap();
    let lx = tap.taps.get(&l.name).unwrap().clone();
    let _ = chain.q_layer(l, &lx, &q).unwrap();
    let r = bench("q_layer/batch32", budget, || {
        let _ = chain.q_layer(l, &lx, &q).unwrap();
    });
    println!("{}", r.row());
}
