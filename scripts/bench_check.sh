#!/usr/bin/env bash
# Serving-path perf guard: run the serve_throughput bench, emit
# BENCH_serve.json at the repo root, and fail if the 4-worker speedup
# over 1 worker on a 64-image batch drops below the floor (default
# 1.5x, override with BENCH_SPEEDUP_FLOOR). Future PRs append their
# BENCH_serve.json to the perf trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:-BENCH_serve.json}"
FLOOR="${BENCH_SPEEDUP_FLOOR:-1.5}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench_check: cargo not on PATH; skipping ($OUT not written)" >&2
    exit 0
fi
if [ ! -f Cargo.toml ]; then
    # The repo has shipped without a manifest since the seed (the xla
    # crate closure is environment-provided); authoring one — with a
    # [[bench]] name = "serve_throughput" harness = false entry — is a
    # prerequisite tracked in ROADMAP.md.
    echo "bench_check: no Cargo.toml at repo root; skipping ($OUT not written)" >&2
    exit 0
fi

BENCH_JSON="$OUT" cargo bench --offline --bench serve_throughput

python3 - "$OUT" "$FLOOR" <<'EOF'
import json, sys
blob = json.load(open(sys.argv[1]))
floor = float(sys.argv[2])
speedup = blob["speedup_w4_vs_w1_b64"]
print(f"bench_check: speedup w4/w1 @ batch 64 = {speedup:.2f}x (floor {floor}x)")
if speedup < floor:
    sys.exit(f"bench_check: FAIL - below the {floor}x floor")
print("bench_check: OK")
EOF
