#!/usr/bin/env bash
# Serving-path perf guard: run the serve_throughput bench, emit
# BENCH_serve.json at the repo root, and fail if
#   (a) the 4-worker speedup over 1 worker on a 64-image batch drops
#       below the floor (default 1.5x, override BENCH_SPEEDUP_FLOOR), or
#   (b) absolute throughput (4 workers, 64-image batch) regresses more
#       than 20% below the best prior entry in bench_history/ (override
#       BENCH_REGRESSION_FRAC, e.g. 0.3 for 30%), or
#   (c) the mixed-model row (tiny+bench interleaved through one shared
#       pool, "mixed_w4_b32x2_images_per_sec") regresses more than the
#       same fraction below the best prior entry that has it (older
#       history entries without the key are skipped, not failed), or
#   (d) the high-connection-count row (256 concurrent pipelined TCP
#       clients through the readiness event loop,
#       "conns256_images_per_sec") regresses the same way — same
#       skip-older-entries rule, or
#   (d') the router-tier row (32 pipelined clients through one router
#       forwarding to 2 backend servers, "router_images_per_sec")
#       regresses the same way — same skip-older-entries rule, or
#   (d'') the reload-under-load row (the 256-connection burst with
#       control-plane registry swaps landing mid-flight,
#       "reload_under_load_images_per_sec") regresses the same way —
#       same skip-older-entries rule, or
#   (e) the batch-service p99 of that 256-connection burst
#       ("p99_service_us", from the same histograms /stats serves)
#       climbs more than the fraction ABOVE the best (lowest) prior
#       entry — latency gates in the opposite direction of throughput;
#       entries predating the key are skipped, or
#   (f) the packed-panel GEMM kernel rate ("gemm_gflops", exact mode)
#       regresses the same way — compared only against prior entries
#       whose "gemm_tile" config matches (entries predating the tiled
#       kernels measured a bare dot product and are skipped).
# Each passing run is appended to bench_history/ as serve_NNN.json, so
# the directory is the PR-over-PR perf trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:-BENCH_serve.json}"
FLOOR="${BENCH_SPEEDUP_FLOOR:-1.5}"
REGRESSION="${BENCH_REGRESSION_FRAC:-0.2}"
HIST_DIR="bench_history"

if ! command -v cargo >/dev/null 2>&1; then
    # No Rust toolchain: still grow the perf trajectory with the Python
    # reference variants (tagged backend "python-ref", so the gates
    # below never compare them against real cargo-bench entries).
    echo "bench_check: cargo not on PATH; running python reference fallback" >&2
    python3 "$(dirname "$0")/bench_ref.py"
    exit 0
fi
if [ ! -f Cargo.toml ]; then
    echo "bench_check: no Cargo.toml at repo root; skipping ($OUT not written)" >&2
    exit 0
fi

BENCH_JSON="$OUT" cargo bench --offline --bench serve_throughput

python3 - "$OUT" "$FLOOR" "$REGRESSION" "$HIST_DIR" <<'EOF'
import glob, json, os, shutil, sys

out, floor, regression, hist_dir = (
    sys.argv[1], float(sys.argv[2]), float(sys.argv[3]), sys.argv[4]
)
blob = json.load(open(out))
# History is partitioned by implementation backend: entries written by
# the python reference fallback (backend "python-ref") must never gate
# real cargo-bench numbers, and vice versa. Entries predating the key
# are all rust runs.
backend = blob.get("backend", "rust")

def ips(blob, workers=4, batch=64):
    for row in blob.get("rows", []):
        if row["workers"] == workers and row["batch"] == batch:
            return row["images_per_sec"]
    return None

speedup = blob["speedup_w4_vs_w1_b64"]
print(f"bench_check: speedup w4/w1 @ batch 64 = {speedup:.2f}x (floor {floor}x)")
if speedup < floor:
    sys.exit(f"bench_check: FAIL - below the {floor}x floor")

cur = ips(blob)
if cur is None:
    sys.exit("bench_check: FAIL - no (workers=4, batch=64) row in the blob")

# Compare against the best prior trajectory entry (absolute throughput
# moves with the hardware; the 20% window absorbs machine noise while
# still catching a real serving-path regression). One pass over the
# history files feeds both metrics.
MIXED = "mixed_w4_b32x2_images_per_sec"
CONNS = "conns256_images_per_sec"
P99 = "p99_service_us"
mixed = blob.get(MIXED)
if mixed is None:
    sys.exit(f"bench_check: FAIL - no {MIXED} in the blob")
conns = blob.get(CONNS)
if conns is None:
    sys.exit(f"bench_check: FAIL - no {CONNS} in the blob")
ROUTER = "router_images_per_sec"
router = blob.get(ROUTER)
if router is None:
    sys.exit(f"bench_check: FAIL - no {ROUTER} in the blob")
RELOAD = "reload_under_load_images_per_sec"
reload_ips = blob.get(RELOAD)
if reload_ips is None:
    sys.exit(f"bench_check: FAIL - no {RELOAD} in the blob")
p99 = blob.get(P99)
if p99 is None:
    sys.exit(f"bench_check: FAIL - no {P99} in the blob")
GEMM = "gemm_gflops"
gemm = blob.get(GEMM)
if gemm is None:
    sys.exit(f"bench_check: FAIL - no {GEMM} in the blob")
# GEMM rates are only comparable within one tile config: entries
# predating the packed-panel kernels measured a bare dot product (no
# "gemm_tile" key) and are skipped, as is any future tile retune.
tile = blob.get("gemm_tile", "")

prior, mixed_prior, conns_prior, router_prior, reload_prior, p99_prior, gemm_prior = (
    [], [], [], [], [], [], []
)
for path in sorted(glob.glob(os.path.join(hist_dir, "serve_*.json"))):
    try:
        entry = json.load(open(path))
        if entry.get("backend", "rust") != backend:
            continue            # other-backend trajectory; not comparable
        v = ips(entry)          # KeyError/TypeError on an off-schema row
        m = entry.get(MIXED)
        c = entry.get(CONNS)
        r = entry.get(ROUTER)
        rl = entry.get(RELOAD)
        p = entry.get(P99)
        g = entry.get(GEMM) if entry.get("gemm_tile", "") == tile else None
    except (ValueError, KeyError, TypeError, AttributeError):
        print(f"bench_check: warning - unreadable history entry {path}", file=sys.stderr)
        continue
    if v is not None:
        prior.append((v, path))
    if m is not None:
        mixed_prior.append((m, path))
    if c is not None:
        conns_prior.append((c, path))
    if r is not None:
        router_prior.append((r, path))
    if rl is not None:
        reload_prior.append((rl, path))
    if p is not None and p > 0:
        p99_prior.append((p, path))
    if g is not None:
        gemm_prior.append((g, path))

def gate(label, value, history, no_prior_msg, unit="img/s"):
    if not history:
        print(no_prior_msg)
        return
    best, best_path = max(history)
    print(
        f"bench_check: {label} {value:.0f} {unit} vs best prior "
        f"{best:.0f} {unit} ({os.path.basename(best_path)}, {len(history)} entries)"
    )
    if value < best * (1.0 - regression):
        sys.exit(
            f"bench_check: FAIL - {label} regressed >{regression:.0%} "
            f"vs {best_path} ({value:.0f} < {best * (1.0 - regression):.0f} {unit})"
        )

gate("w4/b64 throughput", cur, prior,
     "bench_check: no prior bench_history entries; starting the trajectory")
# Mixed-model trajectory: same window, keyed on the multi-model row
# (entries predating the row simply lack the key and are skipped).
gate("mixed 2-model throughput", mixed, mixed_prior,
     f"bench_check: no prior {MIXED} entries; starting the mixed trajectory")
# Event-loop trajectory: 256 concurrent pipelined connections end to
# end; same skip rule for entries predating the row.
gate("256-connection throughput", conns, conns_prior,
     f"bench_check: no prior {CONNS} entries; starting the conns trajectory")
# Router-tier trajectory: pipelined clients through the forwarding
# front-end; same skip rule for entries predating the row.
gate("router-tier throughput", router, router_prior,
     f"bench_check: no prior {ROUTER} entries; starting the router trajectory")
# Reload-under-load trajectory: the 256-connection burst with registry
# swaps mid-flight; same skip rule for entries predating the row.
gate("reload-under-load throughput", reload_ips, reload_prior,
     f"bench_check: no prior {RELOAD} entries; starting the reload trajectory")
# Kernel-rate trajectory: the packed-panel GEMM in exact mode, gated
# only against same-tile-config entries (skip rule above).
gate(f"gemm {tile or 'untiled'}", gemm, gemm_prior,
     f"bench_check: no prior {GEMM} entries for tile {tile!r}; starting the gemm trajectory",
     unit="GFLOP/s")

# Tail-latency trajectory: lower is better, so this gate points the
# other way — fail when the burst's batch-service p99 climbs more than
# the window ABOVE the best (lowest) prior entry. The log2 histogram
# buckets quantize to ~2x steps, so the default 20% window effectively
# fires on a bucket jump — exactly the granularity the trend needs.
if p99_prior:
    best, best_path = min(p99_prior)
    print(
        f"bench_check: batch-service p99 {p99:.0f}us vs best prior "
        f"{best:.0f}us ({os.path.basename(best_path)}, {len(p99_prior)} entries)"
    )
    if p99 > best * (1.0 + regression):
        sys.exit(
            f"bench_check: FAIL - {P99} regressed >{regression:.0%} "
            f"vs {best_path} ({p99:.0f} > {best * (1.0 + regression):.0f}us)"
        )
else:
    print(f"bench_check: no prior {P99} entries; starting the latency trajectory")

os.makedirs(hist_dir, exist_ok=True)
# next index = max existing + 1 (a plain count would re-use an index —
# and silently overwrite an entry — after any gap in the sequence)
taken = []
for path in glob.glob(os.path.join(hist_dir, "serve_*.json")):
    stem = os.path.basename(path)[len("serve_"):-len(".json")]
    if stem.isdigit():
        taken.append(int(stem))
n = max(taken) + 1 if taken else 0
dst = os.path.join(hist_dir, f"serve_{n:03d}.json")
shutil.copyfile(out, dst)
print(f"bench_check: OK (appended {dst})")
EOF
