#!/usr/bin/env python3
"""First-build triage for environments without a Rust toolchain.

`cargo build` has never run in-container (no cargo on PATH since the
seed), so this script performs the static consistency checks a compiler
would do first, catching the class of cross-file drift that accumulates
in review-only development:

  1. delimiter balance per .rs file ((), [], {}), tokenizing away line
     comments, nested block comments, strings (incl. raw strings), and
     char literals (lifetime-aware);
  2. every `mod foo;` declaration resolves to foo.rs or foo/mod.rs;
  3. every source file is reachable from lib.rs/main.rs via mod decls
     (orphan files are listed as warnings, not errors);
  4. every explicit Cargo.toml target path exists;
  5. external crates referenced by `use`/`extern crate` are limited to
     the declared dependency set (std/core/alloc + anyhow + the
     pjrt-gated xla), so an offline build cannot hit a missing crate;
  6. `#[test]` fn names are unique within each file;
  7. every `unsafe fn` / `unsafe {` block carries a `// SAFETY:`
     comment on the same line or within the 14 preceding lines — wide
     enough for a pattern-level comment above a multi-field match arm
     to still count (`unsafe impl` is a type-level promise documented
     at the type and is exempt);
  8. the admin control-plane wire constants (ADMIN_CMD_*, ADMIN_OK,
     ADMIN_ERR, MAX_ADMIN_LINE) exist in rust/src/server/mod.rs, and
     any test file that re-declares one of them (the reload
     conformance suite does, deliberately) carries the exact same
     value — a drifted rename breaks here instead of silently
     hanging a live-swap test against the wrong protocol.

Exit code 1 if any hard check fails. Run: python3 scripts/static_triage.py
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUST_DIRS = [os.path.join(ROOT, "rust"), os.path.join(ROOT, "examples")]
ALLOWED_CRATES = {"std", "core", "alloc", "crate", "super", "self", "anyhow", "aquant", "xla"}

errors = []
warnings = []


def strip_tokens(src: str) -> str:
    """Replace comments/strings/chars with spaces, preserving newlines."""
    out = []
    i, n = 0, len(src)
    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = src.find("\n", i)
            j = n if j < 0 else j
            out.append(" " * (j - i))
            i = j
        elif c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            out.append("".join(ch if ch == "\n" else " " for ch in src[i:j]))
            i = j
        elif c == "r" and re.match(r'r#*"', src[i:]):
            m = re.match(r'r(#*)"', src[i:])
            close = '"' + m.group(1)
            j = src.find(close, i + len(m.group(0)))
            j = n if j < 0 else j + len(close)
            out.append("".join(ch if ch == "\n" else " " for ch in src[i:j]))
            i = j
        elif c == "b" and nxt == '"' or c == '"':
            j = i + (2 if c == "b" else 1)
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            out.append("".join(ch if ch == "\n" else " " for ch in src[i:j]))
            i = j
        elif c == "'":
            # char literal ('x', '\n', '\u{..}') vs lifetime ('a, 'static)
            m = re.match(r"'(\\u\{[0-9a-fA-F_]+\}|\\.|[^\\'])'", src[i:])
            if m:
                out.append(" " * len(m.group(0)))
                i += len(m.group(0))
            else:
                out.append(" ")  # lifetime tick
                i += 1
        else:
            out.append(c)
            i += 1
    return "".join(out)


def check_balance(path: str, src: str):
    code = strip_tokens(src)
    stack = []
    pairs = {")": "(", "]": "[", "}": "{"}
    for ln, line in enumerate(code.split("\n"), 1):
        for ch in line:
            if ch in "([{":
                stack.append((ch, ln))
            elif ch in ")]}":
                if not stack or stack[-1][0] != pairs[ch]:
                    errors.append(f"{path}:{ln}: unbalanced {ch!r}")
                    return code
                stack.pop()
    if stack:
        ch, ln = stack[-1]
        errors.append(f"{path}:{ln}: unclosed {ch!r}")
    return code


def rust_files():
    for d in RUST_DIRS:
        for base, _, files in os.walk(d):
            for f in sorted(files):
                if f.endswith(".rs"):
                    yield os.path.join(base, f)


ADMIN_CONST_RE = re.compile(
    r"(?:pub\s+)?const\s+(ADMIN_[A-Z0-9_]+|MAX_ADMIN_LINE)\s*:\s*[^=]+=\s*([^;]+);"
)


def check_admin_protocol():
    """Check 8: admin wire constants agree between server and tests."""
    src_rel = os.path.join("rust", "src", "server", "mod.rs")
    path = os.path.join(ROOT, src_rel)
    if not os.path.exists(path):
        errors.append(f"{src_rel}: missing (admin-protocol constants live here)")
        return
    with open(path, encoding="utf-8") as fh:
        canon = {m.group(1): m.group(2).strip() for m in ADMIN_CONST_RE.finditer(fh.read())}
    required = {
        "ADMIN_CMD_ADD",
        "ADMIN_CMD_REMOVE",
        "ADMIN_CMD_POLICY",
        "ADMIN_CMD_RELOAD",
        "ADMIN_OK",
        "ADMIN_ERR",
        "MAX_ADMIN_LINE",
    }
    for name in sorted(required - set(canon)):
        errors.append(f"{src_rel}: admin-protocol constant {name} is missing")
    tests_dir = os.path.join(ROOT, "rust", "tests")
    if not os.path.isdir(tests_dir):
        return
    for base, _, files in os.walk(tests_dir):
        for f in sorted(files):
            if not f.endswith(".rs"):
                continue
            rel = os.path.relpath(os.path.join(base, f), ROOT)
            with open(os.path.join(base, f), encoding="utf-8") as fh:
                tsrc = fh.read()
            for m in ADMIN_CONST_RE.finditer(tsrc):
                name, val = m.group(1), m.group(2).strip()
                if name in canon and canon[name] != val:
                    errors.append(
                        f"{rel}: {name} = {val} drifted from "
                        f"{src_rel} ({canon[name]})"
                    )


def main():
    reachable = set()
    stripped = {}
    for path in rust_files():
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        rel = os.path.relpath(path, ROOT)
        code = check_balance(rel, src)
        stripped[rel] = code

        # mod declarations -> files (only for files under rust/src)
        if rel.startswith("rust/src"):
            base = os.path.dirname(path)
            is_root = os.path.basename(path) in ("lib.rs", "main.rs", "mod.rs")
            for m in re.finditer(r"^\s*(?:pub(?:\([^)]*\))?\s+)?mod\s+(\w+)\s*;", code, re.M):
                name = m.group(1)
                here = base if is_root else os.path.join(base, os.path.splitext(os.path.basename(path))[0])
                cands = [os.path.join(here, name + ".rs"), os.path.join(here, name, "mod.rs")]
                hit = next((c for c in cands if os.path.exists(c)), None)
                if hit is None:
                    errors.append(f"{rel}: `mod {name};` has no file ({' or '.join(os.path.relpath(c, ROOT) for c in cands)})")
                else:
                    reachable.add(os.path.relpath(hit, ROOT))

        # external crate allowlist (2018+ uniform paths: a sibling
        # `mod foo;`/`mod foo {}` in the same file legitimizes `use foo::`)
        local_mods = set(re.findall(r"\bmod\s+(\w+)\s*[;{]", code))
        for m in re.finditer(r"^\s*(?:pub(?:\([^)]*\))?\s+)?use\s+([A-Za-z_][A-Za-z0-9_]*)\s*(?:::|;)", code, re.M):
            if m.group(1) not in ALLOWED_CRATES and m.group(1) not in local_mods:
                errors.append(f"{rel}: use of undeclared crate/root `{m.group(1)}`")
        for m in re.finditer(r"^\s*extern\s+crate\s+(\w+)", code, re.M):
            if m.group(1) not in ALLOWED_CRATES:
                errors.append(f"{rel}: extern crate `{m.group(1)}` not in dependency set")

        # duplicate #[test] fn names within one file
        seen = {}
        for m in re.finditer(r"#\[test\]\s*(?:#\[[^\]]*\]\s*)*fn\s+(\w+)", code):
            name = m.group(1)
            if name in seen:
                errors.append(f"{rel}: duplicate #[test] fn {name}")
            seen[name] = True

        # unsafe sites must carry a SAFETY comment on the same line or
        # within the 14 preceding lines of the ORIGINAL source (the
        # stripped code finds the sites; comments only exist in src).
        # The window is wide enough for a pattern-level comment above a
        # multi-field match arm to count for the arm's `unsafe`.
        # `unsafe impl` is a type-level promise documented at the type
        # and is exempt.
        src_lines = src.split("\n")
        for ln, cline in enumerate(code.split("\n"), 1):
            if not re.search(r"\bunsafe\s+fn\b|\bunsafe\s*\{", cline):
                continue
            if re.search(r"\bunsafe\s+impl\b", cline):
                continue
            window = src_lines[max(0, ln - 15):ln]
            if not any("safety" in w.lower() for w in window):
                errors.append(
                    f"{rel}:{ln}: unsafe without a `// SAFETY:` comment in the "
                    f"preceding 14 lines"
                )

    # orphan files under rust/src (never mod-declared)
    # lib/main are crate roots; files under rust/src/bin are standalone
    # [[bin]] targets reached via Cargo.toml, not `mod` declarations
    roots = {"rust/src/lib.rs", "rust/src/main.rs"}
    reachable |= {r for r in stripped if r.startswith("rust/src/bin/")}
    for rel in stripped:
        if not rel.startswith("rust/src"):
            continue
        if rel in roots or os.path.basename(rel) == "mod.rs" and os.path.dirname(rel) == "rust/src":
            continue
        if rel not in reachable and rel not in roots:
            if os.path.basename(rel) not in ("mod.rs",):
                # mod.rs of a dir is reachable iff the dir's mod decl exists
                if rel not in reachable:
                    warnings.append(f"{rel}: not reachable via any `mod` declaration")

    # Cargo.toml target paths
    cargo = os.path.join(ROOT, "Cargo.toml")
    with open(cargo, encoding="utf-8") as fh:
        for ln, line in enumerate(fh, 1):
            m = re.match(r'\s*path\s*=\s*"([^"]+)"', line)
            if m and not os.path.exists(os.path.join(ROOT, m.group(1))):
                errors.append(f"Cargo.toml:{ln}: target path {m.group(1)} does not exist")

    check_admin_protocol()

    for w in warnings:
        print(f"triage: WARN {w}")
    for e in errors:
        print(f"triage: FAIL {e}")
    print(f"triage: {len(list(stripped))} files checked, {len(errors)} errors, {len(warnings)} warnings")
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
