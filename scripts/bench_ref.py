#!/usr/bin/env python3
"""Python reference fallback for the serving kernel microbenches.

When the container has no Rust toolchain (`scripts/bench_check.sh`
cannot run `cargo bench`), this script seeds/extends `bench_history/`
with *reference* entries so the perf trajectory still exists: the same
border quantize-dequantize column math as `rust/src/nn/kernels.rs`,
plus a KC-strip blocked GEMM matching the packed-panel kernels' loop
structure, in two variants —

  * ``scalar``: a pure-Python element loop (the floor any compiled
    implementation must beat), and
  * ``numpy``: the vectorized equivalent (a realistic portable target).

Each variant appends one history entry tagged ``"backend":
"python-ref"`` with ``kernel_backend`` naming the variant.
`bench_check.sh` partitions its regression gates by the ``backend`` key,
so these entries are never compared against real `cargo bench` numbers
(and vice versa) — they only document what the hardware does for the
same math without SIMD.
"""

import glob
import json
import math
import os
import sys
import time

import numpy as np

N = 4096
REPS_SCALAR = 30
REPS_NUMPY = 300

# Blocked-GEMM reference shape: a mid-network conv after im2col
# (196 output pixels x 32 channels x 288 patch rows), mirroring the
# serve_throughput gemm row, blocked in the same KC-element K strips as
# the Rust packed-panel kernels.
GEMM_M, GEMM_N, GEMM_K = 196, 32, 288
GEMM_KC = 256
REPS_GEMM_SCALAR = 3


def fast_offset(u):
    """The kernels.rs rational approximation of sigmoid(2.5u) - 0.5."""
    x = min(max(1.25 * u, -4.0), 4.0)
    x2 = x * x
    p = x * (10395.0 + x2 * (1260.0 + x2 * 21.0))
    q = 10395.0 + x2 * (4725.0 + x2 * (210.0 + x2))
    return 0.5 * (p / q)


def quant_col_scalar(col, b0, b1, b2, s, inv_s, qmin, qmax):
    out = [0.0] * len(col)
    for r, v in enumerate(col):
        xs = v * inv_s
        u = (b2[r] * xs + b1[r]) * xs + b0[r]
        border = 0.5 + fast_offset(u)
        out[r] = s * min(max(math.ceil(xs - border), qmin), qmax)
    return out


def quant_col_numpy(col, b0, b1, b2, s, inv_s, qmin, qmax):
    xs = col * inv_s
    u = (b2 * xs + b1) * xs + b0
    x = np.clip(1.25 * u, -4.0, 4.0)
    x2 = x * x
    p = x * (10395.0 + x2 * (1260.0 + x2 * 21.0))
    q = 10395.0 + x2 * (4725.0 + x2 * (210.0 + x2))
    border = 0.5 + 0.5 * (p / q)
    return s * np.clip(np.ceil(xs - border), qmin, qmax)


def gemm_blocked_scalar(a_rows, b_rows, m, n, k, kc):
    """Pure-Python KC-strip blocked GEMM: out[mi][ni] = A[mi] . B[ni].

    Same loop structure as the Rust packed-panel kernels (K strips
    outermost, accumulators carried across strips) so the floor it sets
    is for the same math, not a different algorithm.
    """
    out = [[0.0] * n for _ in range(m)]
    for k0 in range(0, k, kc):
        k1 = min(k0 + kc, k)
        for mi in range(m):
            arow = a_rows[mi]
            orow = out[mi]
            for ni in range(n):
                brow = b_rows[ni]
                acc = 0.0
                for t in range(k0, k1):
                    acc += arow[t] * brow[t]
                orow[ni] += acc
    return out


def median_ns(fn, reps):
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        fn()
        samples.append(time.perf_counter_ns() - t0)
    samples.sort()
    return float(samples[len(samples) // 2])


def next_slot(hist_dir):
    taken = []
    for path in glob.glob(os.path.join(hist_dir, "serve_*.json")):
        stem = os.path.basename(path)[len("serve_"):-len(".json")]
        if stem.isdigit():
            taken.append(int(stem))
    return max(taken) + 1 if taken else 0


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    hist_dir = os.path.join(root, "bench_history")

    rng = np.random.default_rng(42)
    col = rng.uniform(-4.0, 4.0, N)
    b0 = rng.uniform(-1.0, 1.0, N)
    b1 = rng.uniform(-1.0, 1.0, N)
    b2 = rng.uniform(-1.0, 1.0, N)
    s, inv_s, qmin, qmax = 0.1, 10.0, 0.0, 15.0

    col_l, b0_l, b1_l, b2_l = col.tolist(), b0.tolist(), b1.tolist(), b2.tolist()

    # blocked-GEMM operands: A = im2col patches (M, K), B = weights (N, K)
    ga = rng.uniform(-1.0, 1.0, (GEMM_M, GEMM_K))
    gb = rng.uniform(-1.0, 1.0, (GEMM_N, GEMM_K))
    ga_l, gb_l = ga.tolist(), gb.tolist()

    # the variants must agree on the math before we time them
    ref = np.array(quant_col_scalar(col_l, b0_l, b1_l, b2_l, s, inv_s, qmin, qmax))
    vec = quant_col_numpy(col, b0, b1, b2, s, inv_s, qmin, qmax)
    if not np.allclose(ref, vec, atol=1e-9):
        sys.exit("bench_ref: scalar and numpy border variants disagree")
    gref = np.array(
        gemm_blocked_scalar(ga_l, gb_l, GEMM_M, GEMM_N, GEMM_K, GEMM_KC)
    )
    if not np.allclose(gref, ga @ gb.T, atol=1e-9):
        sys.exit("bench_ref: scalar and numpy GEMM variants disagree")

    variants = [
        (
            "scalar",
            median_ns(
                lambda: quant_col_scalar(col_l, b0_l, b1_l, b2_l, s, inv_s, qmin, qmax),
                REPS_SCALAR,
            ),
            median_ns(
                lambda: gemm_blocked_scalar(
                    ga_l, gb_l, GEMM_M, GEMM_N, GEMM_K, GEMM_KC
                ),
                REPS_GEMM_SCALAR,
            ),
        ),
        (
            "numpy",
            median_ns(
                lambda: quant_col_numpy(col, b0, b1, b2, s, inv_s, qmin, qmax),
                REPS_NUMPY,
            ),
            median_ns(lambda: ga @ gb.T, REPS_NUMPY),
        ),
    ]

    gemm_flops = 2.0 * GEMM_M * GEMM_N * GEMM_K
    os.makedirs(hist_dir, exist_ok=True)
    for name, border_ns, gemm_ns in variants:
        gflops = gemm_flops / max(gemm_ns, 1.0)  # flops/ns == GFLOP/s
        blob = {
            "bench": "serve_throughput",
            "backend": "python-ref",
            "kernel_backend": name,
            "gemm_tile": f"blocked-kc{GEMM_KC}",
            "border_quant_col_ns": round(border_ns, 1),
            "gemm_gflops": round(gflops, 4),
        }
        slot = next_slot(hist_dir)
        dst = os.path.join(hist_dir, f"serve_{slot:03d}.json")
        with open(dst, "w") as f:
            json.dump(blob, f, indent=2)
            f.write("\n")
        print(
            f"bench_ref: {name}: border column {border_ns:.0f}ns, "
            f"gemm {GEMM_M}x{GEMM_N}x{GEMM_K} {gflops:.3f} GFLOP/s -> {dst}"
        )


if __name__ == "__main__":
    main()
