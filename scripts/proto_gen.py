"""Prototype: lower a calibration step (fwd + bwd + Adam) containing a
pallas fake-quant kernel (interpret=True, STE via custom_vjp) to HLO text,
and verify the same numerics in python so the rust side can assert."""
import sys
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc
from jax.experimental import pallas as pl
import functools


def _fq_kernel(x_ref, b_ref, o_ref):
    x = x_ref[...]
    b = b_ref[...]
    o_ref[...] = jnp.clip(jnp.ceil(x - b), 0.0, 3.0)


def _fq_pallas(x, b):
    return pl.pallas_call(
        _fq_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, b)


@jax.custom_vjp
def fake_quant(x, b):
    return _fq_pallas(x, b)


def _fq_fwd(x, b):
    return _fq_pallas(x, b), None


def _fq_bwd(res, g):
    return (g, -g)  # STE: d/dx ~= 1, d/db ~= -1


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def loss_fn(w, b, x, y):
    a = x @ w
    q = fake_quant(a, b)
    return jnp.mean((q - y) ** 2)


def step(w, b, m, v, t, x, y, lr):
    gw, gb = jax.grad(loss_fn, argnums=(0, 1))(w, b, x, y)
    loss = loss_fn(w, b, x, y)
    beta1, beta2, eps = 0.9, 0.999, 1e-8
    t1 = t + 1.0
    m1 = beta1 * m + (1 - beta1) * gb
    v1 = beta2 * v + (1 - beta2) * gb * gb
    mh = m1 / (1 - beta1**t1)
    vh = v1 / (1 - beta2**t1)
    b1 = b - lr * mh / (jnp.sqrt(vh) + eps)
    w1 = w - lr * gw
    return (w1, b1, m1, v1, t1, loss)


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "/tmp/proto_step.hlo.txt"
    N, D, O = 4, 3, 2
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((D, O), f32),  # w
        jax.ShapeDtypeStruct((N, O), f32),  # b (border per-elem, toy)
        jax.ShapeDtypeStruct((N, O), f32),  # m
        jax.ShapeDtypeStruct((N, O), f32),  # v
        jax.ShapeDtypeStruct((), f32),      # t
        jax.ShapeDtypeStruct((N, D), f32),  # x
        jax.ShapeDtypeStruct((N, O), f32),  # y
        jax.ShapeDtypeStruct((), f32),      # lr
    )
    lowered = jax.jit(step).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars")

    # reference numerics for rust assert
    import numpy as np
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(D, O), f32)
    b = jnp.full((N, O), 0.5, f32)
    m = jnp.zeros((N, O), f32)
    v = jnp.zeros((N, O), f32)
    t = jnp.asarray(0.0, f32)
    x = jnp.asarray(rng.rand(N, D), f32)
    y = jnp.asarray(rng.rand(N, O), f32)
    lr = jnp.asarray(0.01, f32)
    outs = jax.jit(step)(w, b, m, v, t, x, y, lr)
    print("loss:", float(outs[5]))
    print("b1[0,0]:", float(outs[1][0, 0]))
    print("w1[0,0]:", float(outs[0][0, 0]))
    np.save("/tmp/proto_inputs.npy", np.concatenate([np.asarray(a).ravel() for a in (w, b, m, v, t, x, y, lr)]))


if __name__ == "__main__":
    main()
