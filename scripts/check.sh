#!/usr/bin/env bash
# Repo-wide check entry point: runs whatever test layers the current
# environment can support and reports what it skipped.
#   - python tests (L1/L2 parity) when pytest is importable
#   - cargo build --release && cargo test -q (tier-1) when a Rust
#     toolchain is present (Cargo.toml ships in-repo; the default
#     feature set is pure Rust, so no network access is needed beyond
#     the anyhow crate)
# Exit code is non-zero if any layer that *ran* failed.
set -uo pipefail
cd "$(dirname "$0")/.."

failed=0
ran=0

if command -v python3 >/dev/null 2>&1 && python3 -c 'import pytest' >/dev/null 2>&1; then
    echo "check: running python tests (python/tests)"
    ran=1
    # test_kernel.py / test_quant.py import `hypothesis`, which some
    # environments (this container included) do not ship; skipping them
    # at collection keeps a clean tree green. They run where it exists.
    ignores=()
    if ! python3 -c 'import hypothesis' >/dev/null 2>&1; then
        echo "check: hypothesis unavailable; skipping test_kernel.py + test_quant.py" >&2
        ignores=(--ignore=python/tests/test_kernel.py --ignore=python/tests/test_quant.py)
    fi
    # ${arr[@]+...} guard: expanding an empty array under `set -u` is an
    # error on bash < 4.4 (stock macOS)
    python3 -m pytest python/tests -q ${ignores[@]+"${ignores[@]}"} || failed=1
else
    echo "check: pytest unavailable; skipping python tests" >&2
fi

if command -v cargo >/dev/null 2>&1; then
    echo "check: running tier-1 (cargo build --release && cargo test -q)"
    ran=1
    (cargo build --release --offline && cargo test -q --offline) || failed=1
else
    echo "check: cargo not on PATH; skipping rust build/tests" >&2
fi

if [ "$ran" -eq 0 ]; then
    echo "check: WARNING - no test layer could run in this environment" >&2
fi
exit "$failed"
