#!/usr/bin/env bash
# Repo-wide check entry point: runs whatever test layers the current
# environment can support and reports what it skipped.
#   - python tests (L1/L2 parity) when pytest is importable
#   - cargo build --release && cargo test -q (tier-1) when a Rust
#     toolchain is present (Cargo.toml ships in-repo; the default
#     feature set is pure Rust, so no network access is needed beyond
#     the anyhow crate)
# Exit code is non-zero if any layer that *ran* failed.
set -uo pipefail
cd "$(dirname "$0")/.."

failed=0
ran=0

if command -v python3 >/dev/null 2>&1 && python3 -c 'import pytest' >/dev/null 2>&1; then
    echo "check: running python tests (python/tests)"
    ran=1
    # test_kernel.py / test_quant.py importorskip `hypothesis`, so they
    # self-skip at collection where it isn't installed — no --ignore
    # plumbing needed here.
    python3 -m pytest python/tests -q || failed=1
else
    echo "check: pytest unavailable; skipping python tests" >&2
fi

if command -v cargo >/dev/null 2>&1; then
    echo "check: running tier-1 (cargo build --release && cargo test -q)"
    ran=1
    (cargo build --release --offline && cargo test -q --offline) || failed=1

    # Connection-conformance suite under an explicit wall-clock guard
    # (in addition to the in-process Watchdog each of its tests arms):
    # these tests drive adversarial sockets against the readiness loop,
    # and a wedged loop must FAIL CI loudly, never hang it. The suite
    # also ran in the plain `cargo test` above; this second, guarded run
    # re-executes only the already-built test binary, so it costs suite
    # runtime, not a rebuild.
    if command -v timeout >/dev/null 2>&1; then
        echo "check: re-running conn_conformance under a 600s timeout guard"
        timeout -k 30 600 cargo test -q --offline --test conn_conformance || failed=1
        # Same guard for the stats-endpoint suite: it scrapes a live
        # server over real sockets, so a wedged loop must fail, not hang.
        echo "check: re-running stats_endpoint under a 600s timeout guard"
        timeout -k 30 600 cargo test -q --offline --test stats_endpoint || failed=1
        # Same guard for the router tier: routers, backends, and
        # killed-backend reconnect loops all run on real sockets.
        echo "check: re-running router_conformance under a 600s timeout guard"
        timeout -k 30 600 cargo test -q --offline --test router_conformance || failed=1
        # Same guard for the control-plane tier: registry swaps land
        # under a live 256-connection load, and a swap that wedges the
        # event loop or drops a draining queue must fail loudly.
        echo "check: re-running reload_conformance under a 600s timeout guard"
        timeout -k 30 600 cargo test -q --offline --test reload_conformance || failed=1
    else
        echo "check: timeout(1) unavailable; relying on the suite's in-process watchdogs" >&2
    fi

    # Style gates, only where the toolchain ships the components
    # (rustup minimal profiles and some containers do not): silently
    # skipped when absent so a bare cargo still gets a green check.
    if cargo fmt --version >/dev/null 2>&1; then
        echo "check: running cargo fmt --check"
        cargo fmt --check || failed=1
    fi
    if cargo clippy --version >/dev/null 2>&1; then
        echo "check: running cargo clippy -D warnings"
        cargo clippy --offline --all-targets -- -D warnings || failed=1
    fi
else
    echo "check: cargo not on PATH; skipping rust build/tests" >&2
fi

if [ "$ran" -eq 0 ]; then
    echo "check: WARNING - no test layer could run in this environment" >&2
fi
exit "$failed"
